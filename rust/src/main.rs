//! `zettastream` — the launcher CLI.
//!
//! ```text
//! zettastream run [key=value ...]       one experiment, report to stdout
//! zettastream bench <fig3..fig9|hybrid|writepath|checkpoint|store|shard|chaos|hotpath|latency|ablations|all> [--quick] [key=value ...]
//! zettastream broker --listen <addr> [key=value ...]
//!                                       standalone broker node on real TCP
//! zettastream list                      the benchmark catalog (Table II)
//! zettastream calibrate                 measure the real data plane, print
//!                                       suggested cost-model overrides
//! zettastream config [key=value ...]    resolve + dump a config
//! ```
//!
//! Keys are `ExperimentConfig::apply` keys (Table I names: np, nc, nmap,
//! ns, cs, recs, replication, nbc, nfs, mode, workload, ...) plus
//! `cost.*` overrides. `run --data_plane=real` loads the AOT artifacts
//! and executes the Layer-1 kernels on the hot path; `run plane=real`
//! runs the cluster on OS threads with RPCs over localhost TCP.

use std::process::ExitCode;
use std::rc::Rc;

use zettastream::cluster::{launch, RunSummary};
use zettastream::compute::ComputeEngine;
use zettastream::config::{parse_kv_file, parse_overrides, DataPlane, ExecPlane, ExperimentConfig};
use zettastream::experiments;
use zettastream::proto::Chunk;
use zettastream::wikipedia::CorpusReader;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let result = match cmd {
        "run" => cmd_run(rest),
        "bench" => cmd_bench(rest),
        "broker" => cmd_broker(rest),
        "list" => cmd_list(),
        "calibrate" => cmd_calibrate(),
        "config" => cmd_config(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command `{other}` (try `zettastream help`)")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!("{}", include_str!("cli_help.txt"));
}

/// Build a config from optional `--config <file>` + key=value overrides.
fn build_config(args: &[String]) -> Result<ExperimentConfig, String> {
    let mut config = ExperimentConfig::default();
    let mut overrides = Vec::new();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        if arg == "--config" {
            let path = it.next().ok_or("--config needs a path")?;
            let body = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let kv = parse_kv_file(&body).map_err(|e| e.to_string())?;
            config.apply(&kv)?;
        } else if arg != "--quick" {
            overrides.push(arg.clone());
        }
    }
    config.apply(&parse_overrides(&overrides)?)?;
    config.validate()?;
    Ok(config)
}

fn make_compute(config: &ExperimentConfig) -> Result<Option<Rc<ComputeEngine>>, String> {
    if config.data_plane != DataPlane::Real {
        return Ok(None);
    }
    ComputeEngine::xla_from_default_dir()
        .map(Some)
        .map_err(|e| format!("{e:#}"))
}

fn print_summary(s: &RunSummary) {
    println!("{}", s.report.row());
    println!(
        "  totals: produced {} consumed {} pullRPCs {} objects {}",
        s.records_produced, s.records_consumed, s.pull_rpcs, s.objects_filled
    );
    if s.planted > 0 || s.matches > 0 {
        println!("  filter: planted {} matched {}", s.planted, s.matches);
    }
    if s.windows_fired > 0 {
        println!("  windows fired: {}", s.windows_fired);
    }
    for (name, value) in &s.report.gauges {
        println!("  gauge {name} = {value:.4}");
    }
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let config = build_config(args)?;
    if config.plane == ExecPlane::Real {
        println!(
            "running `{}` on the real plane: Np={} Nc={} Ns={} CS={}B mode={} write={} \
             workload={} corpus={} recs/producer",
            config.name,
            config.np,
            config.nc,
            config.ns,
            config.producer_chunk,
            config.mode.name(),
            config.write_mode.name(),
            config.workload.name(),
            config.corpus_records,
        );
        let s = zettastream::real::run_cluster(&config)?;
        println!(
            "  totals: produced {} consumed {} logged {} pullRPCs {} objects {}",
            s.records_produced, s.records_consumed, s.tuples_logged, s.pull_rpcs, s.objects_filled
        );
        if s.planted > 0 || s.matches > 0 {
            println!("  filter: planted {} matched {}", s.planted, s.matches);
        }
        println!(
            "  wall: {:.3}s  events {}  ({:.0} events/s)  threads spawned {} joined {}",
            s.wall_secs,
            s.events_processed,
            s.events_processed as f64 / s.wall_secs.max(1e-9),
            s.threads.spawned,
            s.threads.joined,
        );
        return Ok(());
    }
    let compute = make_compute(&config)?;
    println!(
        "running `{}`: Np={} Nc={} Ns={} CS={}B mode={} workload={} NBc={} repl={} plane={:?}",
        config.name,
        config.np,
        config.nc,
        config.ns,
        config.producer_chunk,
        config.mode.name(),
        config.workload.name(),
        config.broker_cores,
        config.replication,
        config.data_plane,
    );
    let summary = launch(&config, compute).run();
    print_summary(&summary);
    Ok(())
}

/// `zettastream broker --listen <addr> [key=value ...]` — a standalone
/// broker node on real TCP, driven by external wire clients (the contract
/// harness in `tests/broker_contract.rs` is the reference client).
fn cmd_broker(args: &[String]) -> Result<(), String> {
    let mut listen: Option<String> = None;
    let mut config_args = Vec::new();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        if arg == "--listen" {
            listen = Some(it.next().ok_or("--listen needs an address")?.clone());
        } else {
            config_args.push(arg.clone());
        }
    }
    let listen = listen.ok_or("broker needs --listen <host:port> (port 0 = ephemeral)")?;
    let config = build_config(&config_args)?;
    zettastream::real::run_broker_server(&listen, &config)
}

fn cmd_bench(args: &[String]) -> Result<(), String> {
    let which = args.first().map(|s| s.as_str()).unwrap_or("all");
    let quick = args.iter().any(|a| a == "--quick");
    if which == "hotpath" {
        // Simulator hot-path throughput: 4 source × 3 write sweep, the
        // cluster-sim acceptance target, and the recorded perf trajectory.
        // The sweep config is fixed on purpose (identical modelled work in
        // every cell, comparable across runs) — refuse overrides instead
        // of silently dropping them.
        if let Some(extra) = args.iter().skip(1).find(|a| *a != "--quick") {
            return Err(format!(
                "bench hotpath runs a fixed sweep config and takes no overrides (got `{extra}`)"
            ));
        }
        let path = std::path::Path::new("BENCH_hotpath.json");
        experiments::hotpath::run_and_record(quick, path);
        return Ok(());
    }
    if which == "latency" {
        // The traced latency surface: every (source × write) cell with the
        // tracer sampling every record, per-stage percentiles to
        // BENCH_latency.json. Fixed config for the same reason as hotpath.
        if let Some(extra) = args.iter().skip(1).find(|a| *a != "--quick") {
            return Err(format!(
                "bench latency runs a fixed sweep config and takes no overrides (got `{extra}`)"
            ));
        }
        let path = std::path::Path::new("BENCH_latency.json");
        experiments::latency::run_and_record(quick, path);
        return Ok(());
    }
    if which == "chaos" {
        // The fail-over chaos harness: scripted broker kills across every
        // (source × write) cell at bc=3/rf=2, golden-totals parity against
        // the same-seed fault-free run, results to BENCH_chaos.json.
        // Fixed config for the same reason as hotpath.
        if let Some(extra) = args.iter().skip(1).find(|a| *a != "--quick") {
            return Err(format!(
                "bench chaos runs a fixed sweep config and takes no overrides (got `{extra}`)"
            ));
        }
        let path = std::path::Path::new("BENCH_chaos.json");
        experiments::chaos::run_and_record(quick, path);
        return Ok(());
    }
    let duration: u64 = if quick { 8 } else { 30 };
    let chunks: &[usize] = if quick { &[4, 32, 128] } else { &experiments::CHUNK_SIZES_KIB };
    let specs = match which {
        "fig3" => vec![experiments::fig3(duration, chunks)],
        "fig4" => vec![experiments::fig4(duration, chunks)],
        "fig5" => vec![experiments::fig5(duration, chunks)],
        "fig6" => vec![experiments::fig6(duration, chunks)],
        "fig7" => vec![experiments::fig7(duration, chunks)],
        "fig8" => vec![experiments::fig8(duration)],
        "fig9" => vec![experiments::fig9(duration)],
        "hybrid" => vec![experiments::ablation_hybrid(duration, chunks)],
        "writepath" => vec![experiments::ablation_writepath(duration, chunks)],
        "checkpoint" => vec![experiments::ablation_checkpoint(duration)],
        "store" => vec![experiments::ablation_store(duration)],
        "shard" => vec![experiments::ablation_shard(duration)],
        "latency-fig" => vec![experiments::ablation_latency(duration)],
        "ablations" => experiments::ablations(duration),
        "all" => {
            let mut v = experiments::all_figures(duration, chunks);
            v.extend(experiments::ablations(duration));
            v
        }
        other => return Err(format!("unknown figure `{other}`")),
    };
    for spec in &specs {
        experiments::run_figure(spec);
        println!();
    }
    Ok(())
}

fn cmd_list() -> Result<(), String> {
    println!("{}", experiments::table2());
    println!(
        "bench targets: fig3 fig4 fig5 fig6 fig7 fig8 fig9 hybrid writepath checkpoint \
         store shard chaos hotpath latency latency-fig ablations all"
    );
    Ok(())
}

fn cmd_config(args: &[String]) -> Result<(), String> {
    let config = build_config(args)?;
    println!("{config:#?}");
    Ok(())
}

/// Measure the real data plane on this host and suggest cost overrides
/// (DESIGN.md §6: the sim plane's per-record costs are calibrated from the
/// real path).
fn cmd_calibrate() -> Result<(), String> {
    println!("calibrating on the local host (artifacts: {:?})",
             zettastream::runtime::ArtifactLibrary::default_dir());
    // memcpy bandwidth (broker append/read service).
    let src = vec![7u8; 64 << 20];
    let mut dst = vec![0u8; 64 << 20];
    let t0 = std::time::Instant::now();
    dst.copy_from_slice(&src);
    let memcpy_bps = 64e6 * 1e3 / t0.elapsed().as_nanos() as f64 * 1e6;
    println!("memcpy bandwidth: {:.1} GB/s  -> cost.append_bw_bps", memcpy_bps / 1e9);

    // native kernels per record.
    let mk_chunk = |records: usize, s: usize| {
        let mut reader = CorpusReader::new(s, records as u64);
        let mut data = vec![0u8; records * s];
        reader.fill_records(&mut data);
        Chunk::real(records as u32, s as u32, Rc::new(data))
    };
    let native = ComputeEngine::native();
    let chunk = mk_chunk(1024, 100);
    for _ in 0..50 {
        native.filter_count(&chunk, b"needle").map_err(|e| format!("{e:#}"))?;
    }
    let st = native.stats();
    let native_filter_ns = st.wall_ns / st.records_processed.max(1);
    println!("native filter: {native_filter_ns} ns/record -> cost.native_record_ns");

    let native2 = ComputeEngine::native();
    let text = mk_chunk(64, 2048);
    for _ in 0..20 {
        native2.wordcount(&text).map_err(|e| format!("{e:#}"))?;
    }
    let st = native2.stats();
    println!(
        "native wordcount: {} ns/record ({} records)",
        st.wall_ns / st.records_processed.max(1),
        st.records_processed
    );

    // XLA path, if artifacts are built.
    match ComputeEngine::xla_from_default_dir() {
        Ok(xla) => {
            for _ in 0..20 {
                xla.filter_count(&chunk, b"needle").map_err(|e| format!("{e:#}"))?;
            }
            let st = xla.stats();
            println!(
                "xla filter (PJRT, interpret-lowered): {} ns/record",
                st.wall_ns / st.records_processed.max(1)
            );
            let xla2 = ComputeEngine::xla_from_default_dir().map_err(|e| format!("{e:#}"))?;
            for _ in 0..5 {
                xla2.wordcount(&text).map_err(|e| format!("{e:#}"))?;
            }
            let st = xla2.stats();
            println!(
                "xla wordcount (PJRT): {} ns/record",
                st.wall_ns / st.records_processed.max(1)
            );
        }
        Err(e) => println!("xla path skipped ({e:#}); run `make artifacts`"),
    }
    println!(
        "\napply overrides like:\n  zettastream run cost.native_record_ns={native_filter_ns} ..."
    );
    Ok(())
}
