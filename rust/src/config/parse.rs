//! Minimal `key = value` config parser + size/CLI helpers.
//!
//! The offline vendor set has no serde/toml, so experiment files use a flat
//! TOML subset: comments (`#`), blank lines, optional `[section]` headers
//! that prefix keys with `section.`, bare or quoted string values.

use std::collections::BTreeMap;
use std::fmt;

/// Ordered key-value view of a config file or CLI override list.
#[derive(Debug, Default, Clone)]
pub struct KvMap {
    entries: BTreeMap<String, String>,
}

impl KvMap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<String>) {
        self.entries.insert(key.into(), value.into());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Config parse failure with line context.
#[derive(Debug)]
pub struct KvError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for KvError {}

/// Parse a config file body.
pub fn parse_kv_file(body: &str) -> Result<KvMap, KvError> {
    let mut map = KvMap::new();
    let mut section = String::new();
    for (idx, raw) in body.lines().enumerate() {
        let line = raw.trim();
        let lineno = idx + 1;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| KvError {
                line: lineno,
                message: format!("unterminated section header `{line}`"),
            })?;
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| KvError {
            line: lineno,
            message: format!("expected `key = value`, got `{line}`"),
        })?;
        let key = key.trim();
        if key.is_empty() {
            return Err(KvError { line: lineno, message: "empty key".into() });
        }
        let mut value = value.trim();
        // strip trailing comment on unquoted values
        if !value.starts_with('"') {
            if let Some(pos) = value.find('#') {
                value = value[..pos].trim_end();
            }
        }
        let value = value.trim_matches('"');
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        map.insert(full_key, value);
    }
    Ok(map)
}

/// Parse `--key=value` CLI overrides (`--` prefix optional).
pub fn parse_overrides<I, S>(args: I) -> Result<KvMap, String>
where
    I: IntoIterator<Item = S>,
    S: AsRef<str>,
{
    let mut map = KvMap::new();
    for arg in args {
        let arg = arg.as_ref();
        let body = arg.strip_prefix("--").unwrap_or(arg);
        let (key, value) = body
            .split_once('=')
            .ok_or_else(|| format!("expected key=value, got `{arg}`"))?;
        if key.is_empty() {
            return Err(format!("empty key in `{arg}`"));
        }
        map.insert(key.trim(), value.trim());
    }
    Ok(map)
}

/// Parse sizes with optional binary suffix: `4096`, `64k`/`64K`/`64KiB`,
/// `8m`/`8MiB`, `1g`. The paper quotes chunk sizes in KiB.
pub fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    let lower = s.to_ascii_lowercase();
    let (digits, mult) = if let Some(p) = lower.strip_suffix("kib").or(lower.strip_suffix("kb")) {
        (p, 1024)
    } else if let Some(p) = lower.strip_suffix("mib").or(lower.strip_suffix("mb")) {
        (p, 1024 * 1024)
    } else if let Some(p) = lower.strip_suffix("gib").or(lower.strip_suffix("gb")) {
        (p, 1024 * 1024 * 1024)
    } else if let Some(p) = lower.strip_suffix('k') {
        (p, 1024)
    } else if let Some(p) = lower.strip_suffix('m') {
        (p, 1024 * 1024)
    } else if let Some(p) = lower.strip_suffix('g') {
        (p, 1024 * 1024 * 1024)
    } else {
        (lower.as_str(), 1)
    };
    let n: usize = digits.trim().parse().ok()?;
    Some(n * mult)
}
