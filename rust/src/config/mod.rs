//! Configuration: every Table I parameter, the cost model, cluster topology.
//!
//! The paper's Table I parameter names are kept verbatim (`Np`, `Nc`,
//! `Nmap`, `Ns`, `CS`, `ReqS`, `RecS`, `Replication`, `NBc`, `NFs`) so an
//! experiment spec reads like the paper's setup section. Configs load from
//! a minimal TOML-subset file (`key = value` under `[section]`; the offline
//! vendor set has no serde/toml) plus `--key=value` CLI overrides.

mod cost;
mod parse;
#[cfg(test)]
mod tests;

pub use cost::{CostModel, NetworkProfile};
pub use parse::{parse_kv_file, parse_overrides, KvError, KvMap};

/// Which source-reader strategy consumers use — the paper's central axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SourceMode {
    /// Continuous synchronous pull RPCs (state-of-the-art Flink/Spark style).
    Pull,
    /// One subscription RPC + shared-memory objects + notifications (ours).
    Push,
    /// The paper's native "C++" pull consumer baseline (no engine overhead).
    NativePull,
    /// Adaptive: start pulling, switch to the push subscription when pull
    /// RPCs are starved by writes (empty polls / broker contention over a
    /// sliding window), fall back with hysteresis. The paper's implied
    /// fourth mode: "push-based **and/or** pull-based".
    Hybrid,
}

impl SourceMode {
    pub const ALL: [SourceMode; 4] =
        [Self::Pull, Self::Push, Self::NativePull, Self::Hybrid];

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pull" => Some(Self::Pull),
            "push" => Some(Self::Push),
            "native" | "nativepull" | "native-pull" | "cpp" => Some(Self::NativePull),
            "hybrid" | "adaptive" => Some(Self::Hybrid),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Pull => "pull",
            Self::Push => "push",
            Self::NativePull => "native",
            Self::Hybrid => "hybrid",
        }
    }
}

/// Which write-path strategy producers use — the write-side mirror of
/// [`SourceMode`] (the paper's "making room for higher ingestion").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WriteMode {
    /// The paper's §V-A baseline: one synchronous Append RPC per request,
    /// `generate → Append → wait ack`.
    SyncRpc,
    /// Asynchronous appends with a bounded in-flight window
    /// (`write_inflight`). Per-partition sequence tracking detects acks
    /// that complete out of send order (`acks_reordered`); the simulated
    /// fabric is FIFO, so the log itself keeps send order.
    Pipelined,
    /// The push-source idea applied to ingestion: one `WriteSubscribe` RPC
    /// registers the colocated producer, which fills free plasma objects
    /// directly and notifies the broker to seal/append them. Backpressure
    /// is object exhaustion, not RPC pacing.
    SharedMem,
}

impl WriteMode {
    pub const ALL: [WriteMode; 3] = [Self::SyncRpc, Self::Pipelined, Self::SharedMem];

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sync" | "syncrpc" | "sync-rpc" => Some(Self::SyncRpc),
            "pipelined" | "pipeline" | "async" => Some(Self::Pipelined),
            "sharedmem" | "shared-mem" | "shm" => Some(Self::SharedMem),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::SyncRpc => "sync",
            Self::Pipelined => "pipelined",
            Self::SharedMem => "sharedmem",
        }
    }
}

/// What the injected fault kills (sim-plane fault injection; see the
/// `checkpoint` module for the recovery protocol).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Kill an operator task on the processing worker. Engine-less modes
    /// (the native baseline) have no worker tasks, so the fault falls back
    /// to a source there.
    Worker,
    /// Kill a source reader.
    Source,
    /// Kill a live shard broker mid-run. Recovery is *not* a checkpoint
    /// rollback: the shard coordinator's failure detector declares the
    /// broker dead on a missed lease and promotes each of its partitions'
    /// standing replicas (see `crate::shard`). Requires `broker_count > 1`
    /// and `replication_factor >= 2` so every partition survives the loss.
    Broker,
}

impl FaultKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "worker" | "task" => Some(Self::Worker),
            "source" | "reader" => Some(Self::Source),
            "broker" | "shard" => Some(Self::Broker),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Worker => "worker",
            Self::Source => "source",
            Self::Broker => "broker",
        }
    }
}

/// The benchmark applications of §V-B (Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    /// Pass over records, count per second (synthetic benchmark 1).
    Count,
    /// Count + grep-style filter on each record (synthetic benchmark 2).
    Filter,
    /// Wikipedia word count (Listing 2, first pipeline).
    WordCount,
    /// Wikipedia windowed word count (5 s window, 1 s slide).
    WindowedWordCount,
}

impl Workload {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "count" => Some(Self::Count),
            "filter" => Some(Self::Filter),
            "wordcount" | "wc" => Some(Self::WordCount),
            "windowedwordcount" | "wwc" | "windowed-wordcount" => Some(Self::WindowedWordCount),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Count => "count",
            Self::Filter => "filter",
            Self::WordCount => "wordcount",
            Self::WindowedWordCount => "windowed-wordcount",
        }
    }

    /// Wikipedia workloads stream 2 KiB text records (paper §V-A).
    pub fn is_text(&self) -> bool {
        matches!(self, Self::WordCount | Self::WindowedWordCount)
    }
}

/// Which execution plane runs the cluster (`plane=` knob).
///
/// Orthogonal to [`DataPlane`]: the data plane decides what a chunk
/// payload *is* (accounting vs real bytes through the kernels), the
/// execution plane decides what delivers the messages — the DES engine's
/// virtual clock, or OS threads with the RPC layer over localhost TCP
/// (`crate::real`). Same actors, same protocol, either plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPlane {
    /// Single-threaded discrete-event simulation (the default).
    Sim,
    /// OS threads + TCP RPCs; plasma stays in-process shared memory.
    Real,
}

impl ExecPlane {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Some(Self::Sim),
            "real" => Some(Self::Real),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Sim => "sim",
            Self::Real => "real",
        }
    }
}

/// How chunk payloads flow through the system (DESIGN.md §2, substitution 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPlane {
    /// Real bytes end-to-end; operators execute the AOT XLA kernels.
    Real,
    /// Byte/record accounting only; same control path, calibrated costs.
    Sim,
}

impl DataPlane {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "real" => Some(Self::Real),
            "sim" => Some(Self::Sim),
            _ => None,
        }
    }
}

/// Which log-storage backend the broker opens (see `broker::store`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StoreMode {
    /// Pure in-memory partition logs — the sim default (pre-subsystem
    /// behavior, retention is the only footprint bound).
    Memory,
    /// Durable tiered log: WAL ring + in-memory tail + cold segment
    /// files with background compaction. Survives broker restarts.
    Durable,
}

impl StoreMode {
    pub const ALL: [StoreMode; 2] = [Self::Memory, Self::Durable];

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "memory" | "mem" => Some(Self::Memory),
            "durable" | "disk" | "tiered" => Some(Self::Durable),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Memory => "memory",
            Self::Durable => "durable",
        }
    }
}

/// Typed validation failures for the shard-topology gates.
///
/// The shard subsystem's callers (the CLI, the cluster launcher, the
/// rebalance tests) match on these; every other cross-field invariant
/// still reports through [`ConfigError::Invalid`]'s message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    /// `Ns` does not divide evenly across `broker_count`: range
    /// assignment would leave brokers with ragged shard sizes.
    PartitionsNotDivisible { partitions: usize, brokers: usize },
    /// `Nc` does not divide evenly across `broker_count`: a consumer's
    /// contiguous partition range would straddle two brokers.
    ConsumersNotDivisible { consumers: usize, brokers: usize },
    /// `replication_factor` outside `1..=broker_count`.
    BadReplicationFactor { factor: usize, brokers: usize },
    /// `fault_kind=broker` on a topology that cannot survive the loss:
    /// killing a broker needs `broker_count > 1` (someone left to promote)
    /// and `replication_factor >= 2` (a standing replica per partition).
    BrokerFaultNeedsReplicas { brokers: usize, factor: usize },
    /// Any other invariant violation, with the human-readable reason.
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::PartitionsNotDivisible { partitions, brokers } => write!(
                f,
                "Ns={partitions} must divide evenly across broker_count={brokers} \
                 (range assignment gives every broker Ns/broker_count partitions)"
            ),
            Self::ConsumersNotDivisible { consumers, brokers } => write!(
                f,
                "Nc={consumers} must divide evenly across broker_count={brokers} \
                 (each consumer's contiguous partition range must map to one broker)"
            ),
            Self::BadReplicationFactor { factor, brokers } => write!(
                f,
                "replication_factor={factor} must be in 1..=broker_count={brokers} \
                 (a replica set cannot outnumber the brokers)"
            ),
            Self::BrokerFaultNeedsReplicas { brokers, factor } => write!(
                f,
                "fault_kind=broker needs broker_count>1 and replication_factor>=2 \
                 (got broker_count={brokers}, replication_factor={factor}): fail-over \
                 promotes each dead partition's standing replica on a surviving broker"
            ),
            Self::Invalid(reason) => f.write_str(reason),
        }
    }
}

impl std::error::Error for ConfigError {}

/// One experiment = the full Table I vector + run controls.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Experiment label used in reports.
    pub name: String,
    /// `Np` — number of producers.
    pub np: usize,
    /// `Nc` — number of consumers == sourceParallelism.
    pub nc: usize,
    /// `Nmap` — mapper parallelism.
    pub nmap: usize,
    /// `Ns` — stream partitions.
    pub ns: usize,
    /// `CS` — producer chunk size in bytes.
    pub producer_chunk: usize,
    /// Consumer chunk size in bytes (pull `CS`; Fig. 4/5/6 fix it to 128 KiB,
    /// Fig. 7 sets it equal to the producer's, Fig. 8 to 8x the producer's).
    pub consumer_chunk: usize,
    /// `RecS` — record size in bytes.
    pub record_size: usize,
    /// `Replication` — 1 (no backup) or 2 (one backup broker on another node).
    pub replication: usize,
    /// Shard brokers the partitions are spread across (1 = the classic
    /// single-broker topology). `>1` enables the shard subsystem: a
    /// coordinator-owned versioned assignment table routes every producer
    /// and source by partition range (see `crate::shard`).
    pub broker_count: usize,
    /// Per-shard replica-set size, in `1..=broker_count`: each partition's
    /// log lives on this many brokers and appends commit on a majority
    /// quorum of replica acks. Generalises the legacy `Replication=2`
    /// single-backup pair (which stays available at `broker_count=1`).
    pub replication_factor: usize,
    /// Shard rebalancing: force one live partition hand-off (drain →
    /// checkpoint cursors → reassign → resume) at this virtual second;
    /// 0 = never. Needs `replication_factor >= 2` so every partition has
    /// a standing replica to promote.
    pub rebalance_at_secs: u64,
    /// Failure detector: coordinator → broker heartbeat period (ms). The
    /// detector only runs when the topology can act on a death
    /// (`broker_count > 1` and `replication_factor >= 2`).
    pub shard_heartbeat_ms: u64,
    /// Failure detector: a broker whose last heartbeat ack is older than
    /// this lease (ms) is declared dead and failed over. Must be at least
    /// one heartbeat period; keep it generous — it races only against a
    /// wedged cluster, never against correctness.
    pub shard_lease_ms: u64,
    /// Sharded writers and sources: per-RPC deadline (ms) before a reply
    /// is presumed lost to a dead broker. The deadline grows exponentially
    /// (capped) across retransmits of the same request; retransmits keep
    /// their RPC id so the broker's idempotence table can re-ack
    /// duplicates (`BrokerDownRetries` counts each one).
    pub rpc_deadline_ms: u64,
    /// `NBc` — broker working cores.
    pub broker_cores: usize,
    /// `NFs` — processing worker slots.
    pub worker_slots: usize,
    /// Source strategy.
    pub mode: SourceMode,
    /// Producer write-path strategy.
    pub write_mode: WriteMode,
    /// Pipelined writer: bounded in-flight append window (requests).
    pub write_inflight: usize,
    /// Shared-memory writer: objects per producer (backpressure window).
    pub write_objects_per_producer: usize,
    /// Writers: bounded retries before a rejected append is surfaced as a
    /// `WriteError` (0 = fail on first rejection).
    pub write_retry_max: u32,
    /// Writers: backoff before each retry (µs).
    pub write_retry_backoff_us: u64,
    /// Benchmark application.
    pub workload: Workload,
    /// Virtual run length in seconds (paper runs 60–180 s).
    pub duration_secs: u64,
    /// Warm-up seconds excluded from the p50 aggregation.
    pub warmup_secs: u64,
    /// Payload handling.
    pub data_plane: DataPlane,
    /// Execution plane: DES engine (`sim`) or OS threads + TCP (`real`).
    pub plane: ExecPlane,
    /// Shared objects per push source (backpressure window).
    pub push_objects_per_source: usize,
    /// Pull poll timeout (µs) — the source waits at most this long before
    /// issuing the next pull RPC even if the last one returned nothing.
    pub pull_timeout_us: u64,
    /// Producer chunk seal timeout (µs); paper: up to 1 ms.
    pub seal_timeout_us: u64,
    /// Word-count window size/slide in seconds (5/1 in the paper).
    pub window_size_secs: u64,
    pub window_slide_secs: u64,
    /// Inter-task queue capacity in batches (credits per upstream).
    pub queue_cap: usize,
    /// Per-producer record budget; 0 = unbounded. Bounds the real-plane
    /// corpus readers (the paper's text producers push ~2 GiB then stop)
    /// AND, when > 0, sim-plane generators of every workload
    /// (`RecordGen::BoundedSim`) — that is what lets the write modes be
    /// cross-checked on identical totals.
    pub corpus_records: u64,
    /// Hybrid: sliding window length, in completed pull RPCs, over which
    /// the source judges whether pulling still pays off.
    pub hybrid_window_polls: usize,
    /// Hybrid: switch pull→push when empty polls exceed this fraction
    /// (permille) of the window.
    pub hybrid_empty_permille: u32,
    /// Hybrid: switch pull→push when the window's mean pull RPC round-trip
    /// exceeds this (µs) — the "pulls starved by writes" contention signal.
    pub hybrid_latency_us: u64,
    /// Hybrid: minimum dwell time after a switch before the next one (ms) —
    /// the hysteresis that prevents flapping.
    pub hybrid_cooldown_ms: u64,
    /// Hybrid: fall back push→pull when no shared object arrives for this
    /// long (ms).
    pub hybrid_idle_ms: u64,
    /// Checkpointing: aligned-barrier interval (ms); 0 disables the
    /// checkpoint subsystem entirely (no coordinator is built).
    pub checkpoint_interval_ms: u64,
    /// Fault injection: kill `fault_kind`'s victim at this virtual second;
    /// 0 disables. Requires checkpointing (recovery needs a restorable
    /// floor protecting the broker log from retention).
    pub fault_at_secs: u64,
    /// Fault injection: what the fault kills.
    pub fault_kind: FaultKind,
    /// Broker log storage backend.
    pub store_mode: StoreMode,
    /// Durable store root directory; empty = an ephemeral per-process
    /// temp directory (removed when the run ends). Point it somewhere
    /// real to survive restarts (the crash-recovery tests do).
    pub store_dir: String,
    /// Log segment capacity (bytes): the in-memory segment size for both
    /// backends, and the durable store's cold flush unit.
    pub store_segment_bytes: u64,
    /// Durable: WAL ring file rotation size (bytes).
    pub store_wal_bytes: u64,
    /// Durable: cold files per partition that trigger a compaction merge.
    pub store_compact_min_segments: usize,
    /// Durable: decoded cold segments cached for readers.
    pub store_cold_cache_segments: usize,
    /// Observability: per-record span sampling rate in permille
    /// (0..=1000). 0 disables the tracing plane entirely — the zero-copy
    /// hot path takes no tracer calls (see the `obs` module's sampling
    /// contract); 1000 traces every request.
    pub trace_sample_permille: u32,
    /// Observability: JSONL trace/event sink path (spans, checkpoint
    /// epochs, hybrid switch-overs, fault/restore events). Empty = no
    /// file is written; events are still buffered when tracing is on.
    pub trace_out: String,
    /// RNG seed.
    pub seed: u64,
    /// Cost model.
    pub cost: CostModel,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "experiment".into(),
            np: 4,
            nc: 4,
            nmap: 8,
            ns: 8,
            producer_chunk: 16 * 1024,
            consumer_chunk: 128 * 1024,
            record_size: 100,
            replication: 1,
            broker_count: 1,
            replication_factor: 1,
            rebalance_at_secs: 0,
            shard_heartbeat_ms: 100,
            shard_lease_ms: 500,
            rpc_deadline_ms: 250,
            broker_cores: 16,
            worker_slots: 16,
            mode: SourceMode::Pull,
            write_mode: WriteMode::SyncRpc,
            write_inflight: 4,
            write_objects_per_producer: 4,
            write_retry_max: 3,
            write_retry_backoff_us: 100,
            workload: Workload::Count,
            duration_secs: 60,
            warmup_secs: 5,
            data_plane: DataPlane::Sim,
            plane: ExecPlane::Sim,
            push_objects_per_source: 4,
            pull_timeout_us: 100,
            seal_timeout_us: 1000,
            window_size_secs: 5,
            window_slide_secs: 1,
            queue_cap: 8,
            corpus_records: 0,
            hybrid_window_polls: 32,
            hybrid_empty_permille: 600,
            hybrid_latency_us: 200,
            hybrid_cooldown_ms: 1000,
            hybrid_idle_ms: 200,
            checkpoint_interval_ms: 0,
            fault_at_secs: 0,
            fault_kind: FaultKind::Worker,
            store_mode: StoreMode::Memory,
            store_dir: String::new(),
            store_segment_bytes: 8 << 20,
            store_wal_bytes: 64 << 20,
            store_compact_min_segments: 4,
            store_cold_cache_segments: 4,
            trace_sample_permille: 0,
            trace_out: String::new(),
            seed: 0x5E77A_57F3A,
            cost: CostModel::default(),
        }
    }
}

impl ExperimentConfig {
    /// `ReqS` — request size: one chunk for each partition a producer
    /// appends to in a single synchronous RPC (Table I).
    pub fn request_size(&self) -> usize {
        self.producer_chunk * self.partitions_per_producer_rpc()
    }

    /// The paper's producers write one chunk per partition of the broker
    /// per RPC; all partitions live on the single storage broker.
    pub fn partitions_per_producer_rpc(&self) -> usize {
        self.ns
    }

    /// Records per producer chunk (chunks are record-framed, never split
    /// a record).
    pub fn records_per_chunk(&self) -> usize {
        (self.producer_chunk / self.record_size).max(1)
    }

    /// Validate the cross-field invariants before launching.
    ///
    /// String-typed convenience wrapper over [`Self::validate_typed`] for
    /// callers that only print the failure.
    pub fn validate(&self) -> Result<(), String> {
        self.validate_typed().map_err(|e| e.to_string())
    }

    /// Validate with typed errors: shard-topology gates report as matchable
    /// [`ConfigError`] variants, everything else as [`ConfigError::Invalid`].
    pub fn validate_typed(&self) -> Result<(), ConfigError> {
        self.validate_shards()?;
        self.validate_rest().map_err(ConfigError::Invalid)
    }

    /// The shard-topology gates (`broker_count` / `replication_factor` /
    /// `rebalance_at_secs` cross-field invariants).
    fn validate_shards(&self) -> Result<(), ConfigError> {
        if self.broker_count == 0 {
            return Err(ConfigError::Invalid("broker_count must be positive".into()));
        }
        if self.replication_factor == 0 || self.replication_factor > self.broker_count {
            return Err(ConfigError::BadReplicationFactor {
                factor: self.replication_factor,
                brokers: self.broker_count,
            });
        }
        if self.broker_count > 1 {
            if self.ns % self.broker_count != 0 {
                return Err(ConfigError::PartitionsNotDivisible {
                    partitions: self.ns,
                    brokers: self.broker_count,
                });
            }
            if self.nc % self.broker_count != 0 {
                return Err(ConfigError::ConsumersNotDivisible {
                    consumers: self.nc,
                    brokers: self.broker_count,
                });
            }
            if self.replication != 1 {
                return Err(ConfigError::Invalid(
                    "broker_count>1 replaces the legacy backup pair; set replication=1 \
                     and use replication_factor for per-shard replica sets"
                        .into(),
                ));
            }
            if self.plane == ExecPlane::Real {
                return Err(ConfigError::Invalid(
                    "plane=real runs the single-broker topology; set broker_count=1 \
                     (sharded brokers over TCP are a later revision)"
                        .into(),
                ));
            }
        }
        if self.rebalance_at_secs > 0 {
            if self.replication_factor < 2 {
                return Err(ConfigError::Invalid(
                    "rebalance_at_secs needs replication_factor >= 2: the hand-off \
                     promotes each partition's standing replica"
                        .into(),
                ));
            }
            if self.rebalance_at_secs >= self.duration_secs {
                return Err(ConfigError::Invalid(format!(
                    "rebalance_at_secs={} must fall inside the run (duration {} s)",
                    self.rebalance_at_secs, self.duration_secs
                )));
            }
        }
        if self.fault_at_secs > 0
            && self.fault_kind == FaultKind::Broker
            && (self.broker_count < 2 || self.replication_factor < 2)
        {
            return Err(ConfigError::BrokerFaultNeedsReplicas {
                brokers: self.broker_count,
                factor: self.replication_factor,
            });
        }
        if self.broker_count > 1 && self.replication_factor >= 2 {
            if self.shard_heartbeat_ms == 0 {
                return Err(ConfigError::Invalid(
                    "shard_heartbeat_ms must be positive (the failure detector's probe \
                     period; raise shard_lease_ms instead to slow detection)"
                        .into(),
                ));
            }
            if self.shard_lease_ms < self.shard_heartbeat_ms {
                return Err(ConfigError::Invalid(format!(
                    "shard_lease_ms={} must be >= shard_heartbeat_ms={} (a lease shorter \
                     than one probe period declares every broker dead)",
                    self.shard_lease_ms, self.shard_heartbeat_ms
                )));
            }
            if self.rpc_deadline_ms == 0 {
                return Err(ConfigError::Invalid(
                    "rpc_deadline_ms must be positive when replica fail-over is armed \
                     (writers and sources need a deadline to escape a dead broker)"
                        .into(),
                ));
            }
        }
        Ok(())
    }

    /// Every non-shard invariant (the original string-reporting checks).
    fn validate_rest(&self) -> Result<(), String> {
        if self.np == 0 || self.ns == 0 {
            return Err("Np and Ns must be positive".into());
        }
        if self.nc == 0 || self.nc > self.ns {
            return Err(format!(
                "Nc={} must be in 1..=Ns={} (one partition is consumed by exactly one consumer)",
                self.nc, self.ns
            ));
        }
        if self.ns % self.nc != 0 {
            return Err(format!(
                "Ns={} must divide evenly among Nc={} consumers",
                self.ns, self.nc
            ));
        }
        if !(1..=2).contains(&self.replication) {
            return Err("Replication must be 1 or 2".into());
        }
        if self.record_size == 0 || self.record_size > self.producer_chunk {
            return Err(format!(
                "RecS={} must fit in the producer chunk ({} B)",
                self.record_size, self.producer_chunk
            ));
        }
        if self.consumer_chunk < self.producer_chunk {
            return Err("consumer chunk must be >= producer chunk".into());
        }
        if self.broker_cores == 0 || self.worker_slots == 0 {
            return Err("NBc and NFs must be positive".into());
        }
        if self.duration_secs <= self.warmup_secs {
            return Err("duration must exceed warmup".into());
        }
        if self.window_slide_secs == 0 || self.window_size_secs < self.window_slide_secs {
            return Err("window size must be >= slide > 0".into());
        }
        if self.write_inflight == 0 {
            return Err("write_inflight must be positive".into());
        }
        if self.write_objects_per_producer == 0 {
            return Err("write_objects_per_producer must be positive".into());
        }
        if self.hybrid_window_polls == 0 {
            return Err("hybrid_window_polls must be positive".into());
        }
        if self.hybrid_empty_permille > 1000 {
            return Err(format!(
                "hybrid_empty_permille={} must be a permille (0..=1000)",
                self.hybrid_empty_permille
            ));
        }
        if self.hybrid_idle_ms == 0 {
            return Err("hybrid_idle_ms must be positive".into());
        }
        if self.fault_at_secs > 0 {
            // Worker/source faults recover by checkpoint rollback + replay,
            // so they need a committed floor protecting the log. A broker
            // fault recovers by replica promotion instead — the quorum
            // replica already holds every acked byte — so checkpointing
            // stays optional there.
            if self.checkpoint_interval_ms == 0 && self.fault_kind != FaultKind::Broker {
                return Err(
                    "fault injection needs checkpointing (checkpoint_interval_ms > 0): \
                     without a committed floor, retention may trim the replay data"
                        .into(),
                );
            }
            if self.fault_at_secs >= self.duration_secs {
                return Err(format!(
                    "fault_at_secs={} must fall inside the run (duration {} s)",
                    self.fault_at_secs, self.duration_secs
                ));
            }
        }
        if self.store_segment_bytes == 0 {
            return Err("store_segment_bytes must be positive".into());
        }
        if self.trace_sample_permille > 1000 {
            return Err(format!(
                "trace_sample_permille={} must be a permille (0..=1000)",
                self.trace_sample_permille
            ));
        }
        if self.plane == ExecPlane::Real {
            // The real plane terminates at quiescence (every produced
            // record consumed), not at a virtual horizon — it needs a
            // bounded workload, and the current scope keeps the
            // checkpoint/fault coordinator and the XLA data plane on the
            // simulator. The latency tracer DOES run here: span
            // timestamps come from a process-wide wall clock (see
            // `obs::Tracer::set_wall_clock`), comparable across node
            // threads.
            if self.corpus_records == 0 {
                return Err(
                    "plane=real needs a bounded workload (corpus_records > 0): real runs \
                     stop at quiescence, not at a virtual horizon"
                        .into(),
                );
            }
            if self.checkpoint_interval_ms > 0 || self.fault_at_secs > 0 {
                return Err(
                    "plane=real does not run the checkpoint/fault coordinator yet; set \
                     checkpoint_interval_ms=0 and fault_at_secs=0"
                        .into(),
                );
            }
            if self.data_plane == DataPlane::Real {
                return Err(
                    "plane=real currently runs the accounting data plane; set data_plane=sim \
                     (the XLA kernels are loaded per-thread in a later revision)"
                        .into(),
                );
            }
            if self.replication != 1 {
                return Err(
                    "plane=real keeps replication in-engine and only supports replication=1"
                        .into(),
                );
            }
        }
        if self.store_mode == StoreMode::Durable {
            if self.store_wal_bytes == 0 {
                return Err("store_wal_bytes must be positive".into());
            }
            if self.store_compact_min_segments < 2 {
                return Err("store_compact_min_segments must be >= 2 (a merge needs two files)"
                    .into());
            }
            if self.store_cold_cache_segments == 0 {
                return Err("store_cold_cache_segments must be positive".into());
            }
        }
        Ok(())
    }

    /// Apply `key=value` overrides (CLI or file body).
    pub fn apply(&mut self, kv: &KvMap) -> Result<(), String> {
        for (key, value) in kv.iter() {
            self.apply_one(key, value)?;
        }
        Ok(())
    }

    fn apply_one(&mut self, key: &str, value: &str) -> Result<(), String> {
        let bad = |k: &str, v: &str| format!("invalid value `{v}` for `{k}`");
        match key {
            "name" => self.name = value.to_string(),
            "np" => self.np = value.parse().map_err(|_| bad(key, value))?,
            "nc" => self.nc = value.parse().map_err(|_| bad(key, value))?,
            "nmap" => self.nmap = value.parse().map_err(|_| bad(key, value))?,
            "ns" => self.ns = value.parse().map_err(|_| bad(key, value))?,
            "producer_chunk" | "cs" => {
                self.producer_chunk = parse::parse_size(value).ok_or_else(|| bad(key, value))?
            }
            "consumer_chunk" => {
                self.consumer_chunk = parse::parse_size(value).ok_or_else(|| bad(key, value))?
            }
            "record_size" | "recs" => {
                self.record_size = parse::parse_size(value).ok_or_else(|| bad(key, value))?
            }
            "replication" => self.replication = value.parse().map_err(|_| bad(key, value))?,
            "broker_count" | "brokers" => {
                self.broker_count = value.parse().map_err(|_| bad(key, value))?
            }
            "replication_factor" | "rf" => {
                self.replication_factor = value.parse().map_err(|_| bad(key, value))?
            }
            "rebalance_at_secs" | "rebalance_at" => {
                self.rebalance_at_secs = value.parse().map_err(|_| bad(key, value))?
            }
            "shard_heartbeat_ms" | "heartbeat_ms" => {
                self.shard_heartbeat_ms = value.parse().map_err(|_| bad(key, value))?
            }
            "shard_lease_ms" | "lease_ms" => {
                self.shard_lease_ms = value.parse().map_err(|_| bad(key, value))?
            }
            "rpc_deadline_ms" | "deadline_ms" => {
                self.rpc_deadline_ms = value.parse().map_err(|_| bad(key, value))?
            }
            "broker_cores" | "nbc" => {
                self.broker_cores = value.parse().map_err(|_| bad(key, value))?
            }
            "worker_slots" | "nfs" => {
                self.worker_slots = value.parse().map_err(|_| bad(key, value))?
            }
            "mode" => self.mode = SourceMode::parse(value).ok_or_else(|| bad(key, value))?,
            "write_mode" | "wmode" => {
                self.write_mode = WriteMode::parse(value).ok_or_else(|| bad(key, value))?
            }
            "write_inflight" => {
                self.write_inflight = value.parse().map_err(|_| bad(key, value))?
            }
            "write_objects_per_producer" => {
                self.write_objects_per_producer = value.parse().map_err(|_| bad(key, value))?
            }
            "write_retry_max" => {
                self.write_retry_max = value.parse().map_err(|_| bad(key, value))?
            }
            "write_retry_backoff_us" => {
                self.write_retry_backoff_us = value.parse().map_err(|_| bad(key, value))?
            }
            "workload" => {
                self.workload = Workload::parse(value).ok_or_else(|| bad(key, value))?
            }
            "duration_secs" | "duration" => {
                self.duration_secs = value.parse().map_err(|_| bad(key, value))?
            }
            "warmup_secs" | "warmup" => {
                self.warmup_secs = value.parse().map_err(|_| bad(key, value))?
            }
            "data_plane" => {
                self.data_plane = DataPlane::parse(value).ok_or_else(|| bad(key, value))?
            }
            "plane" => self.plane = ExecPlane::parse(value).ok_or_else(|| bad(key, value))?,
            "push_objects_per_source" => {
                self.push_objects_per_source = value.parse().map_err(|_| bad(key, value))?
            }
            "pull_timeout_us" => {
                self.pull_timeout_us = value.parse().map_err(|_| bad(key, value))?
            }
            "seal_timeout_us" => {
                self.seal_timeout_us = value.parse().map_err(|_| bad(key, value))?
            }
            "window_size_secs" => {
                self.window_size_secs = value.parse().map_err(|_| bad(key, value))?
            }
            "window_slide_secs" => {
                self.window_slide_secs = value.parse().map_err(|_| bad(key, value))?
            }
            "queue_cap" => self.queue_cap = value.parse().map_err(|_| bad(key, value))?,
            "corpus_records" => {
                self.corpus_records = value.parse().map_err(|_| bad(key, value))?
            }
            "hybrid_window_polls" => {
                self.hybrid_window_polls = value.parse().map_err(|_| bad(key, value))?
            }
            "hybrid_empty_permille" => {
                self.hybrid_empty_permille = value.parse().map_err(|_| bad(key, value))?
            }
            "hybrid_latency_us" => {
                self.hybrid_latency_us = value.parse().map_err(|_| bad(key, value))?
            }
            "hybrid_cooldown_ms" => {
                self.hybrid_cooldown_ms = value.parse().map_err(|_| bad(key, value))?
            }
            "hybrid_idle_ms" => {
                self.hybrid_idle_ms = value.parse().map_err(|_| bad(key, value))?
            }
            "checkpoint_interval_ms" => {
                self.checkpoint_interval_ms = value.parse().map_err(|_| bad(key, value))?
            }
            "fault_at_secs" | "fault_at" => {
                self.fault_at_secs = value.parse().map_err(|_| bad(key, value))?
            }
            "fault_kind" => {
                self.fault_kind = FaultKind::parse(value).ok_or_else(|| bad(key, value))?
            }
            "store_mode" => {
                self.store_mode = StoreMode::parse(value).ok_or_else(|| bad(key, value))?
            }
            "store_dir" => self.store_dir = value.to_string(),
            "store_segment_bytes" => {
                self.store_segment_bytes =
                    parse::parse_size(value).ok_or_else(|| bad(key, value))? as u64
            }
            "store_wal_bytes" => {
                self.store_wal_bytes =
                    parse::parse_size(value).ok_or_else(|| bad(key, value))? as u64
            }
            "store_compact_min_segments" => {
                self.store_compact_min_segments = value.parse().map_err(|_| bad(key, value))?
            }
            "store_cold_cache_segments" => {
                self.store_cold_cache_segments = value.parse().map_err(|_| bad(key, value))?
            }
            "trace_sample_permille" | "trace" => {
                self.trace_sample_permille = value.parse().map_err(|_| bad(key, value))?
            }
            "trace_out" => self.trace_out = value.to_string(),
            "seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            _ if key.starts_with("cost.") => self.cost.apply_one(&key[5..], value)?,
            _ => return Err(format!("unknown config key `{key}`")),
        }
        Ok(())
    }
}
