//! Unit tests: config parsing, validation, cost model.

use super::*;

mod parse_size {
    use super::parse::parse_size;

    #[test]
    fn plain_bytes() {
        assert_eq!(parse_size("4096"), Some(4096));
    }

    #[test]
    fn kib_variants() {
        for s in ["128k", "128K", "128KiB", "128kb", " 128 k "] {
            assert_eq!(parse_size(s), Some(128 * 1024), "{s}");
        }
    }

    #[test]
    fn mib_and_gib() {
        assert_eq!(parse_size("8MiB"), Some(8 * 1024 * 1024));
        assert_eq!(parse_size("1g"), Some(1024 * 1024 * 1024));
    }

    #[test]
    fn rejects_garbage() {
        assert_eq!(parse_size(""), None);
        assert_eq!(parse_size("12q"), None);
        assert_eq!(parse_size("k"), None);
    }
}

mod kv {
    use super::*;

    #[test]
    fn parses_sections_comments_quotes() {
        let body = r#"
# experiment file
name = "fig7"
np = 4

[cost]
dispatch_ns = 900   # tuned
network = infiniband
"#;
        let kv = parse_kv_file(body).unwrap();
        assert_eq!(kv.get("name"), Some("fig7"));
        assert_eq!(kv.get("np"), Some("4"));
        assert_eq!(kv.get("cost.dispatch_ns"), Some("900"));
        assert_eq!(kv.get("cost.network"), Some("infiniband"));
    }

    #[test]
    fn rejects_missing_equals() {
        let err = parse_kv_file("npx 4").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn rejects_unterminated_section() {
        assert!(parse_kv_file("[cost").is_err());
    }

    #[test]
    fn overrides_with_and_without_dashes() {
        let kv = parse_overrides(["--np=8", "mode=push"]).unwrap();
        assert_eq!(kv.get("np"), Some("8"));
        assert_eq!(kv.get("mode"), Some("push"));
    }

    #[test]
    fn overrides_reject_bare_flag() {
        assert!(parse_overrides(["--push"]).is_err());
    }
}

mod experiment {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn apply_table1_keys() {
        let mut cfg = ExperimentConfig::default();
        let kv = parse_overrides([
            "np=8", "nc=8", "ns=8", "cs=32KiB", "recs=100", "replication=2",
            "nbc=4", "nfs=8", "mode=push", "workload=filter",
            "consumer_chunk=256KiB", "cost.dispatch_ns=1200",
        ])
        .unwrap();
        cfg.apply(&kv).unwrap();
        assert_eq!(cfg.np, 8);
        assert_eq!(cfg.producer_chunk, 32 * 1024);
        assert_eq!(cfg.replication, 2);
        assert_eq!(cfg.broker_cores, 4);
        assert_eq!(cfg.mode, SourceMode::Push);
        assert_eq!(cfg.workload, Workload::Filter);
        assert_eq!(cfg.cost.dispatch_ns, 1200);
        cfg.validate().unwrap();
    }

    #[test]
    fn source_mode_names_round_trip() {
        for mode in SourceMode::ALL {
            assert_eq!(SourceMode::parse(mode.name()), Some(mode), "{}", mode.name());
        }
        assert_eq!(SourceMode::parse("hybrid"), Some(SourceMode::Hybrid));
        assert_eq!(SourceMode::parse("adaptive"), Some(SourceMode::Hybrid));
        assert_eq!(SourceMode::parse("bogus"), None);
    }

    #[test]
    fn hybrid_config_round_trip() {
        let mut cfg = ExperimentConfig::default();
        let kv = parse_overrides([
            "mode=hybrid",
            "hybrid_window_polls=16",
            "hybrid_empty_permille=750",
            "hybrid_latency_us=50",
            "hybrid_cooldown_ms=250",
            "hybrid_idle_ms=20",
        ])
        .unwrap();
        cfg.apply(&kv).unwrap();
        assert_eq!(cfg.mode, SourceMode::Hybrid);
        assert_eq!(cfg.hybrid_window_polls, 16);
        assert_eq!(cfg.hybrid_empty_permille, 750);
        assert_eq!(cfg.hybrid_latency_us, 50);
        assert_eq!(cfg.hybrid_cooldown_ms, 250);
        assert_eq!(cfg.hybrid_idle_ms, 20);
        cfg.validate().unwrap();
        // And back through the same parser, paper-config style.
        let body = "mode = hybrid\nhybrid_window_polls = 16\nhybrid_empty_permille = 750\n";
        let kv = parse_kv_file(body).unwrap();
        let mut cfg2 = ExperimentConfig::default();
        cfg2.apply(&kv).unwrap();
        assert_eq!(cfg2.mode, SourceMode::Hybrid);
        assert_eq!(cfg2.hybrid_window_polls, cfg.hybrid_window_polls);
        assert_eq!(cfg2.hybrid_empty_permille, cfg.hybrid_empty_permille);
    }

    #[test]
    fn validate_rejects_bad_hybrid_params() {
        let mut cfg = ExperimentConfig::default();
        cfg.hybrid_window_polls = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.hybrid_empty_permille = 1001;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.hybrid_idle_ms = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn write_mode_names_round_trip() {
        for mode in WriteMode::ALL {
            assert_eq!(WriteMode::parse(mode.name()), Some(mode), "{}", mode.name());
        }
        assert_eq!(WriteMode::parse("async"), Some(WriteMode::Pipelined));
        assert_eq!(WriteMode::parse("shm"), Some(WriteMode::SharedMem));
        assert_eq!(WriteMode::parse("sync-rpc"), Some(WriteMode::SyncRpc));
        assert_eq!(WriteMode::parse("bogus"), None);
    }

    #[test]
    fn write_config_round_trip() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.write_mode, WriteMode::SyncRpc, "the paper's §V-A baseline by default");
        let kv = parse_overrides([
            "write_mode=pipelined",
            "write_inflight=8",
            "write_objects_per_producer=6",
            "write_retry_max=5",
            "write_retry_backoff_us=250",
        ])
        .unwrap();
        cfg.apply(&kv).unwrap();
        assert_eq!(cfg.write_mode, WriteMode::Pipelined);
        assert_eq!(cfg.write_inflight, 8);
        assert_eq!(cfg.write_objects_per_producer, 6);
        assert_eq!(cfg.write_retry_max, 5);
        assert_eq!(cfg.write_retry_backoff_us, 250);
        cfg.validate().unwrap();
        // And the `wmode` shorthand through the file parser.
        let kv = parse_kv_file("wmode = sharedmem\n").unwrap();
        let mut cfg2 = ExperimentConfig::default();
        cfg2.apply(&kv).unwrap();
        assert_eq!(cfg2.write_mode, WriteMode::SharedMem);
    }

    #[test]
    fn validate_rejects_bad_write_params() {
        let mut cfg = ExperimentConfig::default();
        cfg.write_inflight = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.write_objects_per_producer = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn checkpoint_config_round_trip() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.checkpoint_interval_ms, 0, "checkpointing is opt-in");
        assert_eq!(cfg.fault_at_secs, 0, "fault injection is opt-in");
        let kv = parse_overrides([
            "checkpoint_interval_ms=500",
            "fault_at_secs=20",
            "fault_kind=source",
        ])
        .unwrap();
        cfg.apply(&kv).unwrap();
        assert_eq!(cfg.checkpoint_interval_ms, 500);
        assert_eq!(cfg.fault_at_secs, 20);
        assert_eq!(cfg.fault_kind, FaultKind::Source);
        cfg.validate().unwrap();
        // And through the file parser, with the shorthand + worker kind.
        let kv = parse_kv_file("checkpoint_interval_ms = 250\nfault_at = 10\nfault_kind = worker\n")
            .unwrap();
        let mut cfg2 = ExperimentConfig::default();
        cfg2.apply(&kv).unwrap();
        assert_eq!(cfg2.checkpoint_interval_ms, 250);
        assert_eq!(cfg2.fault_at_secs, 10);
        assert_eq!(cfg2.fault_kind, FaultKind::Worker);
        cfg2.validate().unwrap();
    }

    #[test]
    fn fault_kind_names_round_trip() {
        for kind in [FaultKind::Worker, FaultKind::Source, FaultKind::Broker] {
            assert_eq!(FaultKind::parse(kind.name()), Some(kind), "{}", kind.name());
        }
        assert_eq!(FaultKind::parse("task"), Some(FaultKind::Worker));
        assert_eq!(FaultKind::parse("reader"), Some(FaultKind::Source));
        assert_eq!(FaultKind::parse("shard"), Some(FaultKind::Broker));
        assert_eq!(FaultKind::parse("bogus"), None);
    }

    #[test]
    fn broker_fault_config_round_trip() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.shard_heartbeat_ms, 100, "detector probes every 100 ms by default");
        assert_eq!(cfg.shard_lease_ms, 500, "five missed probes declare a broker dead");
        assert_eq!(cfg.rpc_deadline_ms, 250, "RPC deadline armed by default");
        let kv = parse_overrides([
            "broker_count=3",
            "replication_factor=2",
            "fault_at_secs=5",
            "fault_kind=broker",
            "shard_heartbeat_ms=50",
            "shard_lease_ms=300",
            "rpc_deadline_ms=100",
        ])
        .unwrap();
        cfg.apply(&kv).unwrap();
        assert_eq!(cfg.fault_kind, FaultKind::Broker);
        assert_eq!(cfg.shard_heartbeat_ms, 50);
        assert_eq!(cfg.shard_lease_ms, 300);
        assert_eq!(cfg.rpc_deadline_ms, 100);
        // A broker fault recovers by replica promotion, not checkpoint
        // rollback — no checkpoint_interval_ms required.
        assert_eq!(cfg.checkpoint_interval_ms, 0);
        cfg.validate().unwrap();
        // And through the file parser, with the shorthand keys and alias.
        let kv = parse_kv_file(
            "broker_count = 2\nreplication_factor = 2\nfault_at = 3\nfault_kind = shard\n\
             heartbeat_ms = 20\nlease_ms = 80\ndeadline_ms = 40\n",
        )
        .unwrap();
        let mut cfg2 = ExperimentConfig::default();
        cfg2.apply(&kv).unwrap();
        assert_eq!(cfg2.fault_kind, FaultKind::Broker);
        assert_eq!(cfg2.shard_heartbeat_ms, 20);
        assert_eq!(cfg2.shard_lease_ms, 80);
        assert_eq!(cfg2.rpc_deadline_ms, 40);
        cfg2.validate().unwrap();
    }

    #[test]
    fn validate_rejects_broker_fault_without_replicas() {
        // A lone broker has nobody to promote.
        let mut cfg = ExperimentConfig::default();
        cfg.fault_at_secs = 5;
        cfg.fault_kind = FaultKind::Broker;
        assert_eq!(
            cfg.validate(),
            Err(ConfigError::BrokerFaultNeedsReplicas { brokers: 1, factor: 1 })
        );
        // Sharded but unreplicated: the dead primary's log dies with it.
        cfg.broker_count = 3;
        assert_eq!(
            cfg.validate(),
            Err(ConfigError::BrokerFaultNeedsReplicas { brokers: 3, factor: 1 })
        );
        cfg.replication_factor = 2;
        cfg.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_detector_params() {
        // The detector knobs only bind once fail-over is armed
        // (broker_count > 1 and replication_factor >= 2)…
        let mut cfg = ExperimentConfig::default();
        cfg.shard_heartbeat_ms = 0;
        cfg.shard_lease_ms = 0;
        cfg.rpc_deadline_ms = 0;
        cfg.validate().unwrap();
        // …and then every one of them must hold.
        cfg.broker_count = 2;
        cfg.replication_factor = 2;
        assert!(cfg.validate().is_err(), "zero heartbeat rejected");
        cfg.shard_heartbeat_ms = 100;
        assert!(cfg.validate().is_err(), "lease shorter than one probe rejected");
        cfg.shard_lease_ms = 500;
        assert!(cfg.validate().is_err(), "zero rpc deadline rejected");
        cfg.rpc_deadline_ms = 250;
        cfg.validate().unwrap();
    }

    #[test]
    fn validate_rejects_fault_without_checkpointing() {
        let mut cfg = ExperimentConfig::default();
        cfg.fault_at_secs = 10;
        assert!(cfg.validate().is_err(), "recovery needs a committed retention floor");
        cfg.checkpoint_interval_ms = 500;
        cfg.validate().unwrap();
    }

    #[test]
    fn validate_rejects_fault_outside_the_run() {
        let mut cfg = ExperimentConfig::default();
        cfg.checkpoint_interval_ms = 500;
        cfg.fault_at_secs = cfg.duration_secs;
        assert!(cfg.validate().is_err());
        cfg.fault_at_secs = cfg.duration_secs - 1;
        cfg.validate().unwrap();
    }

    #[test]
    fn store_config_round_trip() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.store_mode, StoreMode::Memory, "memory is the sim default");
        assert!(cfg.store_dir.is_empty(), "ephemeral dir is the default");
        let kv = parse_overrides([
            "store_mode=durable",
            "store_dir=/tmp/zs-store",
            "store_segment_bytes=1m",
            "store_wal_bytes=8m",
            "store_compact_min_segments=6",
            "store_cold_cache_segments=2",
        ])
        .unwrap();
        cfg.apply(&kv).unwrap();
        assert_eq!(cfg.store_mode, StoreMode::Durable);
        assert_eq!(cfg.store_dir, "/tmp/zs-store");
        assert_eq!(cfg.store_segment_bytes, 1 << 20);
        assert_eq!(cfg.store_wal_bytes, 8 << 20);
        assert_eq!(cfg.store_compact_min_segments, 6);
        assert_eq!(cfg.store_cold_cache_segments, 2);
        cfg.validate().unwrap();
    }

    #[test]
    fn store_mode_names_round_trip() {
        for mode in StoreMode::ALL {
            assert_eq!(StoreMode::parse(mode.name()), Some(mode), "{}", mode.name());
        }
        assert_eq!(StoreMode::parse("mem"), Some(StoreMode::Memory));
        assert_eq!(StoreMode::parse("disk"), Some(StoreMode::Durable));
        assert_eq!(StoreMode::parse("tiered"), Some(StoreMode::Durable));
        assert_eq!(StoreMode::parse("bogus"), None);
    }

    #[test]
    fn validate_rejects_bad_store_params() {
        let mut cfg = ExperimentConfig::default();
        cfg.store_segment_bytes = 0;
        assert!(cfg.validate().is_err(), "segment size applies to both backends");

        // The durable-only knobs are not validated under memory mode…
        let mut cfg = ExperimentConfig::default();
        cfg.store_wal_bytes = 0;
        cfg.store_compact_min_segments = 1;
        cfg.store_cold_cache_segments = 0;
        cfg.validate().unwrap();
        // …but reject once the durable backend is selected.
        cfg.store_mode = StoreMode::Durable;
        assert!(cfg.validate().is_err());
        cfg.store_wal_bytes = 8 << 20;
        assert!(cfg.validate().is_err(), "compact_min_segments < 2 rejected");
        cfg.store_compact_min_segments = 2;
        assert!(cfg.validate().is_err(), "zero cold cache rejected");
        cfg.store_cold_cache_segments = 1;
        cfg.validate().unwrap();
    }

    #[test]
    fn unknown_key_is_error() {
        let mut cfg = ExperimentConfig::default();
        let kv = parse_overrides(["bogus=1"]).unwrap();
        assert!(cfg.apply(&kv).is_err());
    }

    #[test]
    fn validate_rejects_consumer_exceeding_partitions() {
        let mut cfg = ExperimentConfig::default();
        cfg.nc = 16;
        cfg.ns = 8;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validate_requires_even_partition_split() {
        let mut cfg = ExperimentConfig::default();
        cfg.nc = 3;
        cfg.ns = 8;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_replication() {
        let mut cfg = ExperimentConfig::default();
        cfg.replication = 3;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validate_rejects_record_bigger_than_chunk() {
        let mut cfg = ExperimentConfig::default();
        cfg.record_size = cfg.producer_chunk + 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validate_rejects_consumer_chunk_smaller_than_producer() {
        let mut cfg = ExperimentConfig::default();
        cfg.consumer_chunk = cfg.producer_chunk - 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn request_size_is_chunk_times_partitions() {
        let cfg = ExperimentConfig { producer_chunk: 4096, ns: 8, ..Default::default() };
        assert_eq!(cfg.request_size(), 8 * 4096);
    }

    #[test]
    fn records_per_chunk_floors() {
        let cfg = ExperimentConfig {
            producer_chunk: 1024,
            record_size: 100,
            ..Default::default()
        };
        assert_eq!(cfg.records_per_chunk(), 10);
    }
}

mod cost_model {
    use super::*;

    #[test]
    fn append_cost_scales_with_bytes() {
        let cm = CostModel::default();
        let small = cm.append_cost(1024);
        let big = cm.append_cost(128 * 1024);
        assert!(big > small);
        // 128 KiB at 10 GB/s ~ 13.1 us plus bookkeeping
        assert!((12_000..20_000).contains(&big), "{big}");
    }

    #[test]
    fn read_cost_counts_chunks() {
        let cm = CostModel::default();
        assert!(cm.read_cost(4096, 4) > cm.read_cost(4096, 1));
    }

    #[test]
    fn wire_time_includes_latency_and_bandwidth() {
        let ib = NetworkProfile::INFINIBAND;
        assert_eq!(ib.wire_time(0), ib.latency_ns);
        // 1 MiB at 12.5 GB/s ~ 83.9 us
        let t = ib.wire_time(1024 * 1024);
        assert!((80_000..90_000).contains(&t), "{t}");
    }

    #[test]
    fn commodity_slower_than_infiniband() {
        let b = 64 * 1024;
        assert!(NetworkProfile::COMMODITY.wire_time(b) > NetworkProfile::INFINIBAND.wire_time(b));
    }

    #[test]
    fn cost_overrides() {
        let mut cm = CostModel::default();
        cm.apply_one("engine_record_ns", "123").unwrap();
        assert_eq!(cm.engine_record_ns, 123);
        cm.apply_one("network", "commodity").unwrap();
        assert_eq!(cm.network.name, "commodity-10g");
        assert!(cm.apply_one("nope", "1").is_err());
        assert!(cm.apply_one("dispatch_ns", "abc").is_err());
    }
}
