//! The calibrated cost model (DESIGN.md §6).
//!
//! Every service time the DES charges comes from here. Defaults are
//! calibrated against (a) the magnitudes the paper reports on Aion and
//! (b) `zettastream calibrate`, which measures the *real* data plane
//! (PJRT kernel ns/record, memcpy bandwidth) on the local host.

use crate::sim::Time;

/// Link characteristics between distinct nodes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkProfile {
    /// One-way propagation + NIC latency (ns).
    pub latency_ns: Time,
    /// Link bandwidth in bytes per second.
    pub bandwidth_bps: f64,
    pub name: &'static str,
}

impl NetworkProfile {
    /// Aion's interconnect: Infiniband 100 Gb/s (paper §V-A).
    pub const INFINIBAND: NetworkProfile = NetworkProfile {
        latency_ns: 2_000,
        bandwidth_bps: 12.5e9,
        name: "infiniband-100g",
    };

    /// Commodity 10 GbE — the deployment §VII argues push favours even more.
    pub const COMMODITY: NetworkProfile = NetworkProfile {
        latency_ns: 30_000,
        bandwidth_bps: 1.25e9,
        name: "commodity-10g",
    };

    /// Same-node loopback (colocated broker and worker exchange pointers;
    /// only a small syscall/notification cost remains, charged separately).
    pub const LOOPBACK: NetworkProfile = NetworkProfile {
        latency_ns: 300,
        bandwidth_bps: 40e9,
        name: "loopback",
    };

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "infiniband" | "ib" | "infiniband-100g" => Some(Self::INFINIBAND),
            "commodity" | "10g" | "commodity-10g" => Some(Self::COMMODITY),
            "loopback" => Some(Self::LOOPBACK),
            _ => None,
        }
    }

    /// Wire time for `bytes` on this link (excluding queueing, which the
    /// per-link serialisation in `net` adds).
    pub fn wire_time(&self, bytes: u64) -> Time {
        self.latency_ns + (bytes as f64 / self.bandwidth_bps * 1e9) as Time
    }
}

/// All service-time constants, in nanoseconds unless noted.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    // ---- broker frontend (RAMCloud-style dispatcher/worker, paper §II-B) ----
    /// Dispatcher poll + dispatch per RPC (single dispatcher core).
    pub dispatch_ns: Time,
    /// Fixed worker-side cost to start any RPC handler.
    pub rpc_base_ns: Time,
    /// Broker-side memory write bandwidth for appends (bytes/s).
    pub append_bw_bps: f64,
    /// Broker-side memory read bandwidth for pulls/pushes (bytes/s).
    pub read_bw_bps: f64,
    /// Per-chunk bookkeeping on append (offset index, seal check).
    pub append_chunk_ns: Time,
    /// Per-chunk bookkeeping on read (offset lookup).
    pub read_chunk_ns: Time,

    // ---- push path (shared-memory object store, paper §IV-B) ----
    /// Create/fill bookkeeping per shared object (pointer hand-off, header).
    pub push_object_ns: Time,
    /// Per-record work of the dedicated push thread while building an
    /// object (chunk iteration, framing, offset bookkeeping) — this is what
    /// saturates at Nc=8 in the paper's Fig. 4 ("does not scale ... due to
    /// the limitations of the dedicated thread pushing the chunks").
    pub push_fill_record_ns: Time,
    /// Notification delivery (store -> source task or back), same node.
    pub notify_ns: Time,

    // ---- clients ----
    /// Producer record generation + serialisation, per record.
    pub producer_record_ns: Time,
    /// Engine ("Flink"/JVM) per-record cost on the pull source's serial
    /// fetch loop: network read, decompress, deserialise, emit. This is
    /// what the shared-memory push path eliminates (paper §IV-B).
    pub engine_record_ns: Time,
    /// Per-record cost on the push group's consume thread: pointer access
    /// into the shared object + routing — no copy, no deserialisation.
    pub push_consume_record_ns: Time,
    /// Handling cost per shared-object notification (paper Step 3/4 loop).
    pub push_object_handle_ns: Time,
    /// Native ("C++") per-record consume cost — the Fig. 7 baseline.
    pub native_record_ns: Time,
    /// Client-side per-RPC overhead of the pull fetch loop (request build,
    /// response handling) — dominates when chunks are small (Fig. 8).
    pub pull_rpc_client_ns: Time,
    /// Mapper per-record cost of the count flatMap (RTLogger).
    pub count_map_ns: Time,
    /// Mapper per-record extra cost of the grep filter operator.
    pub filter_record_ns: Time,
    /// Mapper per-token cost of the word-count tokenizer (string split,
    /// object churn — the reason Fig. 9 is CPU-bound).
    pub tokenize_token_ns: Time,
    /// Per-tuple cost of the keyed sum / window operators downstream.
    pub keyed_tuple_ns: Time,
    /// Tokens per 2 KiB text record (sim-plane estimate; the real plane
    /// counts exactly via the wordcount kernel).
    pub tokens_per_record: u64,
    /// Fixed cost for a source task to hand a batch to the next operator
    /// queue (Flink network-stack hop when tasks are not chained).
    pub queue_hop_ns: Time,

    // ---- network ----
    pub network: NetworkProfile,
    /// Colocated processes on a node talk via loopback.
    pub loopback: NetworkProfile,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            dispatch_ns: 1_000,
            rpc_base_ns: 2_000,
            append_bw_bps: 10.0e9,
            read_bw_bps: 12.0e9,
            append_chunk_ns: 1_500,
            read_chunk_ns: 800,
            push_object_ns: 1_000,
            push_fill_record_ns: 100,
            notify_ns: 500,
            producer_record_ns: 200,
            engine_record_ns: 700,
            push_consume_record_ns: 500,
            push_object_handle_ns: 1_500,
            native_record_ns: 60,
            pull_rpc_client_ns: 20_000,
            count_map_ns: 30,
            filter_record_ns: 150,
            tokenize_token_ns: 2_000,
            keyed_tuple_ns: 1_500,
            tokens_per_record: 300,
            queue_hop_ns: 3_000,
            network: NetworkProfile::INFINIBAND,
            loopback: NetworkProfile::LOOPBACK,
        }
    }
}

impl CostModel {
    /// Worker service time to append one chunk of `bytes`.
    pub fn append_cost(&self, bytes: u64) -> Time {
        self.append_chunk_ns + (bytes as f64 / self.append_bw_bps * 1e9) as Time
    }

    /// Worker service time to read `bytes` across `chunks` chunks.
    pub fn read_cost(&self, bytes: u64, chunks: u64) -> Time {
        self.read_chunk_ns * chunks.max(1) + (bytes as f64 / self.read_bw_bps * 1e9) as Time
    }

    /// Push-thread service time to fill one shared object of `bytes`
    /// carrying `records` records.
    pub fn push_fill_cost(&self, bytes: u64, records: u64) -> Time {
        self.push_object_ns
            + records * self.push_fill_record_ns
            + (bytes as f64 / self.read_bw_bps * 1e9) as Time
    }

    /// Apply a `cost.<key>=value` override.
    pub fn apply_one(&mut self, key: &str, value: &str) -> Result<(), String> {
        let bad = || format!("invalid value `{value}` for `cost.{key}`");
        macro_rules! set_ns {
            ($field:ident) => {{
                self.$field = value.parse().map_err(|_| bad())?;
            }};
        }
        match key {
            "dispatch_ns" => set_ns!(dispatch_ns),
            "rpc_base_ns" => set_ns!(rpc_base_ns),
            "append_chunk_ns" => set_ns!(append_chunk_ns),
            "read_chunk_ns" => set_ns!(read_chunk_ns),
            "push_object_ns" => set_ns!(push_object_ns),
            "push_fill_record_ns" => set_ns!(push_fill_record_ns),
            "notify_ns" => set_ns!(notify_ns),
            "producer_record_ns" => set_ns!(producer_record_ns),
            "engine_record_ns" => set_ns!(engine_record_ns),
            "push_consume_record_ns" => set_ns!(push_consume_record_ns),
            "push_object_handle_ns" => set_ns!(push_object_handle_ns),
            "native_record_ns" => set_ns!(native_record_ns),
            "pull_rpc_client_ns" => set_ns!(pull_rpc_client_ns),
            "count_map_ns" => set_ns!(count_map_ns),
            "filter_record_ns" => set_ns!(filter_record_ns),
            "tokenize_token_ns" => set_ns!(tokenize_token_ns),
            "keyed_tuple_ns" => set_ns!(keyed_tuple_ns),
            "tokens_per_record" => set_ns!(tokens_per_record),
            "queue_hop_ns" => set_ns!(queue_hop_ns),
            "append_bw_bps" => self.append_bw_bps = value.parse().map_err(|_| bad())?,
            "read_bw_bps" => self.read_bw_bps = value.parse().map_err(|_| bad())?,
            "network" => self.network = NetworkProfile::parse(value).ok_or_else(bad)?,
            _ => return Err(format!("unknown cost key `cost.{key}`")),
        }
        Ok(())
    }
}
