//! Runtime tests: manifest parsing (always) + artifact load/execute
//! (skipped with a notice when `make artifacts` has not run).

use super::*;

#[test]
fn manifest_parses_rows_and_comments() {
    let body = "# name\tkind\tr\ts\textra\tfile\n\
                filter_r64_s100\tfilter\t64\t100\t6\tfilter_r64_s100.hlo.txt\n\
                \n\
                wordcount_r16_s2048\twordcount\t16\t2048\t8192\twordcount_r16_s2048.hlo.txt\n";
    let metas = parse_manifest(body).unwrap();
    assert_eq!(metas.len(), 2);
    assert_eq!(metas[0].kind, "filter");
    assert_eq!(metas[0].r, 64);
    assert_eq!(metas[0].s, 100);
    assert_eq!(metas[0].extra, 6);
    assert_eq!(metas[1].name, "wordcount_r16_s2048");
}

#[test]
fn manifest_rejects_bad_columns() {
    assert!(parse_manifest("a\tb\tc\n").is_err());
    assert!(parse_manifest("a\tb\tx\t100\t6\tf\n").is_err());
}

/// Artifact-dependent tests run only when the library is present; the
/// integration suite (rust/tests) requires it unconditionally.
#[cfg(feature = "xla")]
fn try_lib() -> Option<ArtifactLibrary> {
    let dir = ArtifactLibrary::default_dir();
    match ArtifactLibrary::load(&dir) {
        Ok(lib) => Some(lib),
        Err(e) => {
            eprintln!("skipping artifact test ({e:#}); run `make artifacts`");
            None
        }
    }
}

#[cfg(feature = "xla")]
#[test]
fn loads_and_selects_variants() {
    let Some(lib) = try_lib() else { return };
    assert!(lib.count() >= 3, "quick set has 3 variants");
    assert!(lib.kinds().contains(&"filter"));
    let v = lib.select("filter", 100, 10).expect("filter s=100 exists");
    assert!(v.meta.r >= 10);
    assert!(lib.select("filter", 100, 1_000_000).is_none(), "r too large");
    assert!(lib.select("filter", 9999, 1).is_none(), "unknown s");
    assert!(lib.max_r("filter", 100).unwrap() >= 64);
}

#[cfg(feature = "xla")]
#[test]
fn filter_variant_executes_end_to_end() {
    let Some(lib) = try_lib() else { return };
    let v = lib.select("filter", 100, 64).expect("filter_r64_s100");
    let r = v.meta.r;
    // chunk: record 3 contains the needle "needle" at byte 10
    let mut data = vec![0u8; r * 100];
    data[3 * 100 + 10..3 * 100 + 16].copy_from_slice(b"needle");
    let chunk = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U8,
        &[r, 100],
        &data,
    )
    .unwrap();
    let mut pat = vec![0u8; 16];
    pat[..6].copy_from_slice(b"needle");
    let pattern =
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, &[16], &pat)
            .unwrap();
    let nvalid = xla::Literal::from(10i32);
    let out = v.execute(&[chunk, pattern, nvalid]).unwrap();
    assert_eq!(out.len(), 3, "(flags, matches, records)");
    let flags = out[0].to_vec::<i32>().unwrap();
    assert_eq!(flags[3], 1);
    assert_eq!(flags.iter().sum::<i32>(), 1);
    assert_eq!(out[1].get_first_element::<i32>().unwrap(), 1);
    assert_eq!(out[2].get_first_element::<i32>().unwrap(), 10);
}
