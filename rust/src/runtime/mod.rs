//! PJRT runtime: load the AOT artifacts and execute them from rust.
//!
//! The interchange format is HLO **text** (`python/compile/aot.py`): jax
//! >= 0.5 serialises `HloModuleProto`s with 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects, while the text parser reassigns ids
//! (see /opt/xla-example/README.md). Each artifact is one chunk-shape
//! *variant* of a Layer-2 graph; `manifest.tsv` lists them.
//!
//! Compilation happens once at load; execution is the request-path hot
//! call. Python never runs here.

#[cfg(test)]
mod tests;

#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One row of `manifest.tsv`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VariantMeta {
    pub name: String,
    /// `filter`, `wordcount` or `window_sum`.
    pub kind: String,
    /// Record rows of the chunk tensor (window count for `window_sum`).
    pub r: usize,
    /// Record size in bytes (bucket count for `window_sum`).
    pub s: usize,
    /// Kind-specific: pattern length / buckets / unused.
    pub extra: usize,
    pub file: String,
}

/// Parse a manifest body (tab-separated, `#` comments).
pub fn parse_manifest(body: &str) -> Result<Vec<VariantMeta>> {
    let mut out = Vec::new();
    for (i, line) in body.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 6 {
            bail!("manifest line {}: want 6 columns, got {}", i + 1, cols.len());
        }
        out.push(VariantMeta {
            name: cols[0].to_string(),
            kind: cols[1].to_string(),
            r: cols[2].parse().with_context(|| format!("manifest line {}: r", i + 1))?,
            s: cols[3].parse().with_context(|| format!("manifest line {}: s", i + 1))?,
            extra: cols[4].parse().with_context(|| format!("manifest line {}: extra", i + 1))?,
            file: cols[5].to_string(),
        });
    }
    Ok(out)
}

/// A compiled variant ready to execute.
#[cfg(feature = "xla")]
pub struct LoadedVariant {
    pub meta: VariantMeta,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla")]
impl LoadedVariant {
    /// Execute with literal inputs; returns the flattened tuple outputs.
    pub fn execute(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.meta.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.meta.name))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(tuple.to_tuple()?)
    }
}

/// The artifact library: every compiled variant, indexed by kind.
#[cfg(feature = "xla")]
pub struct ArtifactLibrary {
    client: xla::PjRtClient,
    variants: HashMap<String, Vec<LoadedVariant>>, // kind -> sorted by r asc
    dir: PathBuf,
}

/// Stub for builds without the `xla` feature: loading always fails with a
/// pointer at the opt-in flag; the sim plane never gets here.
#[cfg(not(feature = "xla"))]
pub struct ArtifactLibrary {}

#[cfg(not(feature = "xla"))]
impl ArtifactLibrary {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "built without the `xla` feature: cannot load artifacts from {} \
             (rebuild with `cargo build --features xla`)",
            dir.as_ref().display()
        )
    }
}

impl ArtifactLibrary {
    /// The default artifact directory: `$ZETTA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ZETTA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(feature = "xla")]
impl ArtifactLibrary {
    /// Load + compile every artifact in `dir` (expects `manifest.tsv`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.tsv");
        let body = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("reading {} (run `make artifacts`)", manifest_path.display())
        })?;
        let metas = parse_manifest(&body)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut variants: HashMap<String, Vec<LoadedVariant>> = HashMap::new();
        for meta in metas {
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", meta.name))?;
            variants
                .entry(meta.kind.clone())
                .or_default()
                .push(LoadedVariant { meta, exe });
        }
        for list in variants.values_mut() {
            list.sort_by_key(|v| v.meta.r);
        }
        Ok(Self { client, variants, dir })
    }

    /// Smallest variant of `kind` with matching `s` and `r >= r_min`
    /// (callers pad the record axis up to the variant's `r`).
    pub fn select(&self, kind: &str, s: usize, r_min: usize) -> Option<&LoadedVariant> {
        self.variants
            .get(kind)?
            .iter()
            .find(|v| v.meta.s == s && v.meta.r >= r_min)
    }

    /// Largest `r` available for `(kind, s)` — callers split bigger chunks.
    pub fn max_r(&self, kind: &str, s: usize) -> Option<usize> {
        self.variants
            .get(kind)?
            .iter()
            .filter(|v| v.meta.s == s)
            .map(|v| v.meta.r)
            .max()
    }

    pub fn kinds(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.variants.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn count(&self) -> usize {
        self.variants.values().map(|v| v.len()).sum()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
