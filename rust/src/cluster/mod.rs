//! The launcher: materialise an [`ExperimentConfig`] into a running cluster.
//!
//! Topology (paper §V-A): node 0 hosts the storage broker, the processing
//! worker and the shared object store (*colocated* — the premise of the
//! push design); node 1 hosts the producers ("deployed separately from the
//! streaming architecture"); node 2 hosts the backup broker when
//! `Replication = 2`.
//!
//! Task index space: sources take `0..Nc`, then each pipeline stage's
//! tasks in order. The launcher builds the pipeline for the configured
//! workload (Listings 1 & 2), wires credits, registers everything in the
//! task registry and returns a [`Cluster`] ready to `run`.
//!
//! Sources are built through the [`SourceRegistry`], producers through
//! the [`WriterRegistry`], and the broker's log storage through the
//! [`StoreRegistry`]: the launcher resolves `config.mode` to a
//! [`crate::source::SourceFactory`], `config.write_mode` to a
//! [`crate::producer::WriterFactory`] and `config.store_mode` to a
//! [`crate::broker::StoreFactory`], and never names a concrete source,
//! producer or storage type — plug a new mechanism in by registering a
//! factory and launching with [`launch_with`] / [`launch_full`].

#[cfg(test)]
mod tests;

use crate::broker::{Broker, BrokerParams, StoreParams, StoreRegistry, DEFAULT_SEGMENT_BYTES};
use crate::checkpoint::{
    CheckpointControl, CheckpointCoordinator, CheckpointStats, CoordinatorParams,
};
use crate::compute::SharedCompute;
use crate::config::{DataPlane, ExperimentConfig, FaultKind};
use crate::metrics::{Class, ExperimentReport, MetricsHub, SharedMetrics};
use crate::net::{Network, SharedNetwork};
use crate::ops::{CountOp, FilterOp, KeyedSumOp, Operator, TokenizerOp, WindowedSumOp};
use crate::pipeline::{OpKind, Pipeline};
use crate::plasma::{ObjectStore, SharedStore};
use crate::producer::{WriteStatKey, WriteStats, WriterActor, WriterRegistry, WriterWiring};
use crate::net::NodeId;
use crate::proto::{Msg, PartitionId};
use crate::shard::{
    BrokerShard, ShardCoordinator, ShardCoordinatorParams, ShardState, ShardTable, SharedShard,
};
use crate::sim::{ActorId, Engine, MILLIS, SECOND};
use crate::source::{SourceActor, SourceRegistry, SourceStats, SourceWiring, StatKey};
use crate::worker::{OperatorTask, TaskParams, TaskRegistry};

// The needle constants moved next to the generator that plants them; the
// historic re-export keeps the public path alive.
pub use crate::producer::{FILTER_NEEDLE, PLANT_PERMILLE};

/// Node 0: broker + worker + plasma store (the colocated premise).
pub const NODE_COLOCATED: usize = 0;
/// Node 1: producers (deployed separately, except the sharedmem writers).
pub const NODE_PRODUCERS: usize = 1;
/// Node 2: backup broker when `Replication = 2`.
pub const NODE_BACKUP: usize = 2;

/// A built cluster, ready to run.
pub struct Cluster {
    pub engine: Engine<Msg>,
    pub config: ExperimentConfig,
    pub metrics: SharedMetrics,
    pub net: SharedNetwork,
    pub store: SharedStore,
    pub compute: Option<SharedCompute>,
    /// The first (at `broker_count = 1`: only) broker — kept for the
    /// single-broker call sites and tests.
    pub broker: ActorId,
    /// Every shard broker, by table index (`vec![broker]` when unsharded).
    pub brokers: Vec<ActorId>,
    pub backup: Option<ActorId>,
    pub producers: Vec<ActorId>,
    pub sources: Vec<ActorId>,
    pub tasks: Vec<ActorId>,
    pub pipeline: Option<Pipeline>,
    /// The checkpoint coordinator, when `checkpoint_interval_ms > 0`.
    pub coordinator: Option<ActorId>,
    /// The published shard view, when `broker_count > 1`.
    pub shard: Option<SharedShard>,
    /// The shard coordinator actor, when `broker_count > 1`.
    pub shard_coordinator: Option<ActorId>,
}

/// End-of-run summary: the report plus cross-checkable totals.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub report: ExperimentReport,
    /// Records producers got acked.
    pub records_produced: u64,
    /// Records the sources consumed.
    pub records_consumed: u64,
    /// Needles planted by synthetic producers (real plane).
    pub planted: u64,
    /// Matches found by filter operators / native consumers (real plane).
    pub matches: u64,
    /// Windows fired by windowed aggregations.
    pub windows_fired: u64,
    /// Pull RPCs issued in total.
    pub pull_rpcs: u64,
    /// Shared objects filled in total.
    pub objects_filled: u64,
    /// Total tuples logged by the RTLogger points (records for count/
    /// filter pipelines, tokens for word-count pipelines).
    pub tuples_logged: u64,
    /// Tuples aggregated by windowed-sum operators (rolled back with the
    /// operator snapshots, so it cross-checks exactly-once under faults).
    pub windowed_tuples: u64,
    /// Aggregated per-source statistics (uniform across all modes).
    pub sources: SourceStats,
    /// Aggregated per-writer statistics (uniform across all write modes).
    pub writers: WriteStats,
    /// Checkpoint/recovery accounting (all zero when checkpointing is off).
    pub checkpoints: CheckpointStats,
    /// Per-stage latency percentiles from the tracing plane (empty when
    /// `trace_sample_permille = 0`) — see [`crate::obs`].
    pub latency: crate::obs::LatencyReport,
}

/// Build a cluster from a config with the built-in source and write modes.
/// `compute` is required for the real data plane (pass `None` on the sim
/// plane).
pub fn launch(config: &ExperimentConfig, compute: Option<SharedCompute>) -> Cluster {
    launch_with(&SourceRegistry::builtin(), &WriterRegistry::builtin(), config, compute)
}

/// [`launch_full`] with the built-in store backends — the pluggable path
/// for out-of-tree source or writer modes.
pub fn launch_with(
    source_registry: &SourceRegistry,
    writer_registry: &WriterRegistry,
    config: &ExperimentConfig,
    compute: Option<SharedCompute>,
) -> Cluster {
    launch_full(
        source_registry,
        writer_registry,
        &StoreRegistry::builtin(),
        config,
        compute,
    )
}

/// Build a cluster resolving `config.mode` / `config.write_mode` /
/// `config.store_mode` against caller-supplied registries — the fully
/// pluggable path.
pub fn launch_full(
    source_registry: &SourceRegistry,
    writer_registry: &WriterRegistry,
    store_registry: &StoreRegistry,
    config: &ExperimentConfig,
    compute: Option<SharedCompute>,
) -> Cluster {
    config.validate().expect("invalid experiment config");
    if config.data_plane == DataPlane::Real {
        assert!(compute.is_some(), "real data plane needs a compute engine");
    }
    let factory = source_registry.expect(config.mode);
    let writer_factory = writer_registry.expect(config.write_mode);
    let mut engine = Engine::new(config.seed);
    let metrics = MetricsHub::shared();
    metrics
        .borrow_mut()
        .tracer
        .configure(config.trace_sample_permille, &config.trace_out);
    let net = Network::shared(config.cost.network, config.cost.loopback);
    let store = ObjectStore::shared();
    let registry = TaskRegistry::shared();
    let partitions: Vec<PartitionId> = (0..config.ns).map(PartitionId).collect();
    let checkpoint = (config.checkpoint_interval_ms > 0).then(CheckpointControl::shared);

    // ---- brokers -------------------------------------------------------
    // `broker_count = 1` takes the classic single-broker (+ optional
    // backup pair) path unchanged; `broker_count > 1` builds the sharded
    // fleet under an assignment table (see `crate::shard`).
    let shard = (config.broker_count > 1).then(|| {
        ShardState::shared(ShardTable::build(
            config.ns,
            config.broker_count,
            config.replication_factor,
            config.seed,
        ))
    });
    let (broker, brokers, backup) = match &shard {
        None => {
            let (broker, backup) = build_brokers(
                &mut engine,
                config,
                store_registry,
                factory.broker_push_threads(),
                &partitions,
                &net,
                &store,
                &metrics,
            );
            (broker, vec![broker], backup)
        }
        Some(sh) => {
            let brokers = build_shard_brokers(
                &mut engine,
                config,
                store_registry,
                factory.broker_push_threads(),
                &partitions,
                sh,
                &net,
                &store,
                &metrics,
            );
            (brokers[0], brokers, None)
        }
    };

    // ---- producers (one generic path through the writer registry) -------
    let writer_wiring = WriterWiring {
        config,
        producer_node: NODE_PRODUCERS,
        broker,
        broker_node: NODE_COLOCATED,
        partitions: partitions.clone(),
        metrics: metrics.clone(),
        net: net.clone(),
        store: store.clone(),
        shard: shard.clone(),
    };
    let producers = writer_factory.build(&writer_wiring, &mut engine);

    // ---- pipeline tasks (not for engine-less modes) ---------------------
    let pipeline = factory
        .uses_pipeline()
        .then(|| Pipeline::for_workload(config.workload, config.nc, config.nmap));
    let (tasks, stage0) = build_pipeline_tasks(
        &mut engine,
        config,
        &pipeline,
        &registry,
        &metrics,
        &checkpoint,
        &compute,
    );

    // ---- sources (one generic path through the factory registry) --------
    let wiring = SourceWiring {
        config,
        node: NODE_COLOCATED,
        broker,
        broker_node: NODE_COLOCATED,
        downstream: stage0,
        metrics: metrics.clone(),
        net: net.clone(),
        store: store.clone(),
        registry: registry.clone(),
        compute: compute.clone(),
        checkpoint: checkpoint.clone(),
        shard: shard.clone(),
    };
    let sources = factory.build(&wiring, &mut engine);

    // ---- shard coordinator (owns the table's lifecycle) ------------------
    let shard_coordinator = shard.as_ref().map(|sh| {
        engine.add_actor(Box::new(ShardCoordinator::new(
            ShardCoordinatorParams {
                node: NODE_COLOCATED,
                rebalance_at: config.rebalance_at_secs * SECOND,
                // The failure detector arms only when a death is
                // survivable: rf >= 2 leaves a standing replica to
                // promote. At rf = 1 a declaration could only strand the
                // dead primary's partitions, so the probes stay off.
                heartbeat: if config.replication_factor >= 2 {
                    config.shard_heartbeat_ms * MILLIS
                } else {
                    0
                },
                lease: if config.replication_factor >= 2 {
                    config.shard_lease_ms * MILLIS
                } else {
                    0
                },
                sources: sources.clone(),
                cost: config.cost.clone(),
            },
            sh.clone(),
            net.clone(),
        )))
    });

    // ---- checkpoint coordinator + fault injection ------------------------
    let coordinator = checkpoint.as_ref().map(|cp| {
        let id = engine.add_actor(Box::new(CheckpointCoordinator::new(
            CoordinatorParams {
                interval_ns: config.checkpoint_interval_ms * MILLIS,
                node: NODE_COLOCATED,
                // Commit floors fan out to every broker: a partition's
                // floor must survive its log changing primaries.
                brokers: brokers.iter().map(|&b| (b, NODE_COLOCATED)).collect(),
                sources: sources.clone(),
                tasks: tasks.clone(),
                partitions: partitions.clone(),
                cost: config.cost.clone(),
            },
            cp.clone(),
            net.clone(),
            metrics.clone(),
        )));
        // Sources and tasks were built first; close the loop so their
        // barrier/failure acks can address the coordinator.
        cp.borrow_mut().coordinator = Some(id);
        id
    });
    if config.fault_at_secs > 0 {
        let victim = match config.fault_kind {
            // Engine-less modes (native) have no worker tasks; the fault
            // falls back to a source so every mode stays faultable.
            FaultKind::Worker => tasks.first().copied().unwrap_or(sources[0]),
            FaultKind::Source => sources[0],
            // Kill the *last* shard broker: broker 0 doubles as the
            // default wiring home, so the last one exercises the
            // re-routing paths without also perturbing the defaults.
            // Validation guarantees broker_count > 1 here.
            FaultKind::Broker => *brokers.last().expect("validate: broker_count > 1"),
        };
        engine.schedule(
            config.fault_at_secs * SECOND,
            victim,
            Msg::Fault { kind: config.fault_kind },
        );
    }

    Cluster {
        engine,
        config: config.clone(),
        metrics,
        net,
        store,
        compute,
        broker,
        brokers,
        backup,
        producers,
        sources,
        tasks,
        pipeline,
        coordinator,
        shard,
        shard_coordinator,
    }
}

/// Build the `broker_count` shard brokers (all on the colocated node),
/// fill the shared shard view's roster, and install each broker's
/// [`BrokerShard`]. Every broker hosts every partition in its log store —
/// the table decides which it *serves* as primary; the rest it only
/// mirrors as a standing replica.
#[allow(clippy::too_many_arguments)]
fn build_shard_brokers(
    engine: &mut Engine<Msg>,
    config: &ExperimentConfig,
    store_registry: &StoreRegistry,
    push_threads: usize,
    partitions: &[PartitionId],
    shard: &SharedShard,
    net: &SharedNetwork,
    store: &SharedStore,
    metrics: &SharedMetrics,
) -> Vec<ActorId> {
    let worker_cores = (config.broker_cores - push_threads).max(1);
    let mut ids = Vec::with_capacity(config.broker_count);
    for b in 0..config.broker_count {
        let mut store_params = StoreParams::from_config(config);
        if let Some(dir) = store_params.dir.take() {
            // A durable fleet needs per-broker roots — N WALs in one
            // directory would clobber each other.
            store_params.dir = Some(dir.join(format!("broker{b}")));
        }
        let log_store = store_registry
            .expect(store_params.mode)
            .open(&store_params, partitions)
            .unwrap_or_else(|e| {
                panic!("opening `{}` store failed: {e}", store_params.mode.name())
            });
        ids.push(engine.add_actor(Box::new(Broker::with_store(
            BrokerParams {
                node: NODE_COLOCATED,
                worker_cores,
                push_threads,
                store: store_params,
                partitions: partitions.to_vec(),
                backup: None,
                is_backup: false,
                cost: config.cost.clone(),
            },
            log_store,
            net.clone(),
            store.clone(),
            metrics.clone(),
            b,
        ))));
    }
    let peers: Vec<(ActorId, NodeId)> = ids.iter().map(|&id| (id, NODE_COLOCATED)).collect();
    shard.borrow_mut().brokers = peers.clone();
    let table = shard.borrow().table.clone();
    for (b, &id) in ids.iter().enumerate() {
        engine
            .actor_as::<Broker>(id)
            .expect("just built")
            .set_shard(BrokerShard::new(b, table.clone(), peers.clone()));
    }
    ids
}

/// Build the backup (when `Replication = 2`) and primary broker actors
/// into `engine`, resolving the log backend through `store_registry`.
/// Returns `(primary, backup)`.
///
/// Shared by [`launch_full`] and the real-plane node builders
/// (`crate::real`) — one broker construction path, two execution planes.
#[allow(clippy::too_many_arguments)]
pub fn build_brokers(
    engine: &mut Engine<Msg>,
    config: &ExperimentConfig,
    store_registry: &StoreRegistry,
    push_threads: usize,
    partitions: &[PartitionId],
    net: &SharedNetwork,
    store: &SharedStore,
    metrics: &SharedMetrics,
) -> (ActorId, Option<ActorId>) {
    // The backup holds only the replication mirror — an in-memory log
    // regardless of the primary's backend (the paper replicates for
    // availability; durability is the primary store's job).
    let backup = (config.replication == 2).then(|| {
        engine.add_actor(Box::new(Broker::new(
            BrokerParams {
                node: NODE_BACKUP,
                worker_cores: config.broker_cores,
                push_threads: 0,
                store: StoreParams::memory(DEFAULT_SEGMENT_BYTES),
                partitions: Vec::new(),
                backup: None,
                is_backup: true,
                cost: config.cost.clone(),
            },
            net.clone(),
            store.clone(),
            metrics.clone(),
            1,
        )))
    });
    let worker_cores = (config.broker_cores - push_threads).max(1);
    let store_params = StoreParams::from_config(config);
    let log_store = store_registry
        .expect(store_params.mode)
        .open(&store_params, partitions)
        .unwrap_or_else(|e| panic!("opening `{}` store failed: {e}", store_params.mode.name()));
    let broker = engine.add_actor(Box::new(Broker::with_store(
        BrokerParams {
            node: NODE_COLOCATED,
            worker_cores,
            push_threads,
            store: store_params,
            partitions: partitions.to_vec(),
            backup: backup.map(|b| (b, NODE_BACKUP)),
            is_backup: false,
            cost: config.cost.clone(),
        },
        log_store,
        net.clone(),
        store.clone(),
        metrics.clone(),
        0,
    )));
    (broker, backup)
}

/// Build the configured workload's operator tasks into `engine` and
/// register them. Returns `(task actor ids, stage-0 task indices)` — the
/// stage-0 indices are what sources feed.
///
/// Shared by [`launch_full`] and the real-plane node builders
/// (`crate::real`) — one pipeline construction path, two execution
/// planes. `pipeline` is `None` for engine-less source modes (native).
pub fn build_pipeline_tasks(
    engine: &mut Engine<Msg>,
    config: &ExperimentConfig,
    pipeline: &Option<Pipeline>,
    registry: &crate::worker::SharedRegistry,
    metrics: &SharedMetrics,
    checkpoint: &Option<crate::checkpoint::SharedCheckpoint>,
    compute: &Option<SharedCompute>,
) -> (Vec<ActorId>, Vec<usize>) {
    let mut tasks = Vec::new();
    let mut stage_task_idxs: Vec<Vec<usize>> = Vec::new();
    if let Some(p) = pipeline {
        let mut next_idx = config.nc;
        for stage in &p.stages {
            let idxs: Vec<usize> = (0..stage.parallelism).map(|k| next_idx + k).collect();
            next_idx += stage.parallelism;
            stage_task_idxs.push(idxs);
        }
        for (si, stage) in p.stages.iter().enumerate() {
            let downstream: Vec<usize> = stage_task_idxs.get(si + 1).cloned().unwrap_or_default();
            // Stage 0 is fed by the logical source tasks (indices 0..Nc);
            // later stages by the previous stage — the channel set a
            // checkpoint barrier aligns over.
            let upstream: Vec<usize> = if si == 0 {
                (0..config.nc).collect()
            } else {
                stage_task_idxs[si - 1].clone()
            };
            for &task_idx in &stage_task_idxs[si] {
                let op = make_op(stage.op, config, &downstream, compute);
                let task = OperatorTask::new(
                    TaskParams {
                        task_idx,
                        queue_cap: config.queue_cap,
                        downstream: downstream.clone(),
                        upstream: upstream.clone(),
                        tick_ns: config.window_slide_secs * SECOND,
                        cost: config.cost.clone(),
                        checkpoint: checkpoint.clone(),
                    },
                    vec![op],
                    registry.clone(),
                    metrics.clone(),
                );
                let id = engine.add_actor(Box::new(task));
                registry.borrow_mut().register(task_idx, id);
                tasks.push(id);
            }
        }
    }
    let stage0: Vec<usize> = stage_task_idxs.first().cloned().unwrap_or_default();
    (tasks, stage0)
}

fn make_op(
    kind: OpKind,
    config: &ExperimentConfig,
    downstream: &[usize],
    compute: &Option<SharedCompute>,
) -> Box<dyn Operator> {
    let real = config.data_plane == DataPlane::Real;
    let compute = real.then(|| compute.clone().expect("real plane needs compute"));
    match kind {
        OpKind::Count => Box::new(CountOp::default()),
        OpKind::Filter => Box::new(FilterOp::new(FILTER_NEEDLE, compute)),
        OpKind::Tokenizer => Box::new(TokenizerOp::new(
            downstream.to_vec(),
            compute,
            config.cost.tokens_per_record,
        )),
        OpKind::KeyedSum => Box::new(KeyedSumOp::new()),
        OpKind::WindowedSum => Box::new(WindowedSumOp::new(
            (config.window_size_secs / config.window_slide_secs) as usize,
            compute,
        )),
    }
}

impl Cluster {
    /// Run the experiment for its configured duration and summarise.
    pub fn run(mut self) -> RunSummary {
        let horizon = self.config.duration_secs * SECOND;
        self.engine.run_until(horizon);
        self.finish()
    }

    /// Collect gauges + totals and build the report.
    pub fn finish(mut self) -> RunSummary {
        let now = self.engine.now();
        // Broker utilisation gauges. A broker actor that fails the
        // downcast is a hard error — silently skipping the export would
        // strip the utilisation gauges every figure reads, the same
        // corruption rationale as the source-stats panic below. Shard
        // broker `i > 0` exports under `broker{i}`; broker 0 keeps the
        // bare `broker` prefix every existing figure reads.
        for (i, &bid) in self.brokers.clone().iter().enumerate() {
            let prefix = if i == 0 { "broker".to_string() } else { format!("broker{i}") };
            self.engine
                .actor_as::<Broker>(bid)
                .unwrap_or_else(|| panic!("broker {bid} is not a Broker actor"))
                .export_gauges(now, &prefix);
        }
        if let Some(backup) = self.backup {
            self.engine
                .actor_as::<Broker>(backup)
                .unwrap_or_else(|| panic!("backup {backup} is not a Broker actor"))
                .export_gauges(now, "backup");
        }
        // Source-side totals, through the uniform trait API. A source that
        // is not a registry-built `SourceActor` is a hard error — silently
        // dropping its stats would corrupt every total below.
        let mut source_stats = SourceStats::default();
        for &sid in &self.sources {
            let actor = self.engine.actor_as::<SourceActor>(sid).unwrap_or_else(|| {
                panic!("source {sid} was not built through the SourceFactory registry")
            });
            source_stats.merge(&actor.stats());
        }
        let records_consumed = source_stats.records_consumed;
        let mut matches = source_stats.extra(StatKey::Matches);
        let source_threads = source_stats.threads;
        // Producer totals, through the uniform write-path trait API — the
        // same hard-error contract as the sources.
        let mut writer_stats = WriteStats::default();
        for &pid in &self.producers {
            let actor = self.engine.actor_as::<WriterActor>(pid).unwrap_or_else(|| {
                panic!("producer {pid} was not built through the WriterFactory registry")
            });
            writer_stats.merge(&actor.stats());
        }
        let records_produced = writer_stats.records_sent;
        let planted = writer_stats.planted;
        // Operator state (matches, windows).
        let mut windows_fired = 0;
        let mut windowed_tuples = 0;
        for &tid in &self.tasks {
            if let Some(t) = self.engine.actor_as::<OperatorTask>(tid) {
                if let Some(f) = t.op_as::<FilterOp>(0) {
                    matches += f.matches;
                }
                if let Some(w) = t.op_as::<WindowedSumOp>(0) {
                    windows_fired += w.windows_fired;
                    windowed_tuples += w.total_tuples;
                }
            }
        }
        // Checkpoint/recovery accounting, through the coordinator.
        let mut checkpoints = CheckpointStats::default();
        if let Some(cid) = self.coordinator {
            let c = self.engine.actor_as::<CheckpointCoordinator>(cid).unwrap_or_else(|| {
                panic!("coordinator {cid} is not a CheckpointCoordinator actor")
            });
            checkpoints = c.stats();
        }
        checkpoints.records_replayed = source_stats.extra(StatKey::RecordsReplayed);
        // Shard hand-off accounting, through the shard coordinator.
        let shard_stats = self.shard_coordinator.map(|scid| {
            self.engine
                .actor_as::<ShardCoordinator>(scid)
                .unwrap_or_else(|| panic!("shard coordinator {scid} has the wrong actor type"))
                .stats()
        });
        {
            let mut m = self.metrics.borrow_mut();
            m.set_gauge("source_threads", source_threads as f64);
            m.set_gauge("writer_threads", writer_stats.threads as f64);
            m.set_gauge(
                "write_append_latency_us",
                writer_stats.mean_append_ns() as f64 / 1e3,
            );
            m.set_gauge(
                "slots_used",
                self.pipeline.as_ref().map(|p| p.slots_used()).unwrap_or(self.config.nc) as f64,
            );
            m.set_gauge("store_reserved_bytes", self.store.borrow().reserved_bytes() as f64);
            m.set_gauge("cross_node_bytes", self.net.borrow().cross_node_bytes() as f64);
            if let Some(ref ss) = shard_stats {
                m.set_gauge("shard.brokers", self.config.broker_count as f64);
                m.set_gauge("shard.rebalances", ss.rebalances as f64);
                m.set_gauge("shard.partitions_moved", ss.partitions_moved as f64);
                m.set_gauge("shard.handoff_ms", ss.handoff_ns as f64 / 1e6);
                m.set_gauge("shard.failovers", ss.failovers as f64);
                m.set_gauge("shard.promotions", ss.promotions as f64);
                m.set_gauge("shard.detection_ms", ss.detection_ns as f64 / 1e6);
                m.set_gauge(
                    "write_broker_down_retries",
                    writer_stats.extra(WriteStatKey::BrokerDownRetries) as f64,
                );
                m.set_gauge(
                    "source_broker_down_retries",
                    source_stats.extra(StatKey::BrokerDownRetries) as f64,
                );
            }
            if self.coordinator.is_some() {
                m.set_gauge("checkpoint.epochs", checkpoints.epochs_completed as f64);
                m.set_gauge("checkpoint.epochs_skipped", checkpoints.epochs_skipped as f64);
                m.set_gauge("checkpoint.mean_epoch_ms", checkpoints.mean_epoch_ns() as f64 / 1e6);
                m.set_gauge("checkpoint.max_epoch_ms", checkpoints.epoch_ns_max as f64 / 1e6);
                m.set_gauge("checkpoint.max_align_ms", checkpoints.align_ns_max as f64 / 1e6);
                m.set_gauge("checkpoint.mean_align_ms", checkpoints.align_ns_mean as f64 / 1e6);
                m.set_gauge("checkpoint.recoveries", checkpoints.recoveries as f64);
                m.set_gauge("checkpoint.recovery_ms", checkpoints.last_recovery_ns as f64 / 1e6);
                m.set_gauge("checkpoint.replayed_records", checkpoints.records_replayed as f64);
            }
            if let Some(c) = &self.compute {
                let st = c.stats();
                m.set_gauge("compute_kernel_calls", (st.filter_calls + st.wordcount_calls) as f64);
                m.set_gauge("compute_wall_ns", st.wall_ns as f64);
                m.set_gauge("compute_records", st.records_processed as f64);
            }
            // Tracing-plane gauges (queue pressure, poll efficiency, append
            // RTT) — empty when the tracer is off.
            for (name, value) in m.tracer.gauges(self.config.duration_secs) {
                m.set_gauge(name, value);
            }
            if let Err(e) = m.tracer.write_sink() {
                eprintln!("warning: trace sink write failed: {e}");
            }
        }
        let latency = self.metrics.borrow().tracer.report();
        let metrics = self.metrics.borrow();
        let report = ExperimentReport::from_hub(
            &self.config.name,
            &metrics,
            self.config.warmup_secs,
            self.config.duration_secs,
        );
        RunSummary {
            report,
            records_produced,
            records_consumed,
            planted,
            matches,
            windows_fired,
            windowed_tuples,
            pull_rpcs: metrics.total(Class::PullRpcs),
            objects_filled: metrics.total(Class::ObjectsFilled),
            tuples_logged: metrics.total(Class::ConsumerTuples),
            sources: source_stats,
            writers: writer_stats,
            checkpoints,
            latency,
        }
    }
}
