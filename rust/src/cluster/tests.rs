//! Launcher tests: full clusters on the sim plane.

use super::*;
use crate::config::{parse_overrides, ExperimentConfig, WriteMode};
use crate::producer::{WriteStatKey, WriterRegistry};
use crate::source::{SourceRegistry, StatKey};

fn cfg(overrides: &[&str]) -> ExperimentConfig {
    let mut c = ExperimentConfig {
        duration_secs: 5,
        warmup_secs: 1,
        ..Default::default()
    };
    c.apply(&parse_overrides(overrides.iter().copied()).unwrap()).unwrap();
    c.validate().unwrap();
    c
}

#[test]
fn pull_cluster_runs_and_reports() {
    let summary = launch(&cfg(&["mode=pull", "np=2", "nc=2", "ns=4"]), None).run();
    assert!(summary.report.producers.p50 > 100_000.0, "{:?}", summary.report.producers);
    assert!(summary.report.consumers.p50 > 100_000.0, "{:?}", summary.report.consumers);
    assert!(summary.pull_rpcs > 0);
    assert_eq!(summary.objects_filled, 0, "pull mode fills no objects");
    assert_eq!(summary.report.gauge("source_threads"), Some(4.0), "2 per pull consumer");
}

#[test]
fn push_cluster_runs_and_reports() {
    let summary = launch(&cfg(&["mode=push", "np=2", "nc=2", "ns=4"]), None).run();
    assert!(summary.report.consumers.p50 > 100_000.0);
    assert!(summary.objects_filled > 0, "push path fills objects");
    assert_eq!(summary.pull_rpcs, 0, "push issues no pull RPCs");
    assert_eq!(summary.report.gauge("source_threads"), Some(2.0), "the Fig. 4 claim");
}

#[test]
fn native_cluster_runs_and_reports() {
    let summary = launch(&cfg(&["mode=native", "np=2", "nc=2", "ns=4"]), None).run();
    assert!(summary.report.consumers.p50 > 100_000.0);
    assert!(summary.pull_rpcs > 0);
    assert_eq!(summary.report.gauge("source_threads"), Some(2.0), "1 per native consumer");
}

#[test]
fn hybrid_cluster_runs_and_reports() {
    let summary = launch(&cfg(&["mode=hybrid", "np=2", "nc=2", "ns=4"]), None).run();
    assert!(summary.report.consumers.p50 > 100_000.0);
    assert!(summary.records_consumed <= summary.records_produced);
    assert!(summary.records_consumed > 0);
    // 2 threads per source while pulling, 1 while pushing — the run may
    // end in either phase.
    let threads = summary.report.gauge("source_threads").expect("gauge set");
    assert!((2.0..=4.0).contains(&threads), "source_threads {threads}");
}

#[test]
fn hybrid_switches_to_push_under_write_heavy_load() {
    // Eight producers against a 2-core broker starve the pull RPCs; with
    // the contention threshold at 1 µs the sources must take the push
    // hand-off — and the push path must then carry data.
    let summary = launch(
        &cfg(&[
            "mode=hybrid",
            "np=8",
            "nc=2",
            "ns=8",
            "cs=64KiB",
            "nbc=2",
            "hybrid_latency_us=1",
            "hybrid_window_polls=4",
            "hybrid_cooldown_ms=0",
        ]),
        None,
    )
    .run();
    assert!(
        summary.sources.extra(StatKey::SwitchesToPush) >= 1,
        "write-heavy load must push the hybrid sources off the pull path: {:?}",
        summary.sources
    );
    assert!(summary.objects_filled > 0, "push path served objects after the switch");
    assert!(summary.records_consumed <= summary.records_produced);
    assert!(summary.sources.pulls_issued >= 4, "monitoring window ran on pulls first");
}

#[test]
fn all_builtin_modes_run_through_the_registry() {
    // The acceptance gate: every mode builds through the one generic
    // factory path and reports uniform stats.
    for mode in crate::config::SourceMode::ALL {
        let mode_kv = format!("mode={}", mode.name());
        let summary = launch(&cfg(&[mode_kv.as_str(), "np=2", "nc=2", "ns=4"]), None).run();
        assert!(summary.records_consumed > 0, "{}: progress", mode.name());
        assert!(summary.sources.threads > 0, "{}: threads accounted", mode.name());
    }
}

#[test]
#[should_panic(expected = "no source factory registered")]
fn unregistered_mode_is_a_hard_error() {
    let config = cfg(&["mode=push", "np=1", "nc=1", "ns=2"]);
    launch_with(&SourceRegistry::empty(), &WriterRegistry::builtin(), &config, None);
}

#[test]
#[should_panic(expected = "no writer factory registered")]
fn unregistered_write_mode_is_a_hard_error() {
    let config = cfg(&["mode=push", "np=1", "nc=1", "ns=2"]);
    launch_with(&SourceRegistry::builtin(), &WriterRegistry::empty(), &config, None);
}

#[test]
#[should_panic(expected = "no store factory registered")]
fn unregistered_store_mode_is_a_hard_error() {
    let config = cfg(&["mode=push", "np=1", "nc=1", "ns=2", "store_mode=durable"]);
    launch_full(
        &SourceRegistry::builtin(),
        &WriterRegistry::builtin(),
        &StoreRegistry::empty(),
        &config,
        None,
    );
}

#[test]
fn durable_store_cluster_runs_and_exports_gauges() {
    let summary = launch(
        &cfg(&[
            "mode=pull",
            "np=2",
            "nc=2",
            "ns=4",
            "store_mode=durable",
            "store_segment_bytes=256KiB",
        ]),
        None,
    )
    .run();
    assert!(summary.records_consumed > 0);
    let wal = summary.report.gauge("broker.store_wal_records").expect("durable gauges on");
    assert!(wal > 0.0, "every append hit the WAL");
    assert!(
        summary.report.gauge("broker.store_segments_flushed").expect("gauge") > 0.0,
        "sealed segments reached the cold tier"
    );
}

#[test]
fn durable_store_matches_memory_on_bounded_totals() {
    // The cluster-level golden check (one cell; the full source × write
    // matrix lives in tests/durable_store.rs): identical bounded totals
    // whichever backend holds the log.
    let mk = |store_kv: &str| {
        let mut c = cfg(&["mode=push", "np=2", "nc=2", "ns=4", store_kv]);
        c.corpus_records = 10_000;
        c.duration_secs = 20;
        c
    };
    let mem = launch(&mk("store_mode=memory"), None).run();
    let dur = launch(&mk("store_mode=durable"), None).run();
    assert_eq!(mem.records_produced, dur.records_produced, "producers unaffected");
    assert_eq!(mem.records_consumed, dur.records_consumed, "consumers unaffected");
    assert_eq!(dur.records_consumed, dur.records_produced, "bounded stream drains");
}

#[test]
fn all_builtin_write_modes_run_through_the_registry() {
    for wmode in WriteMode::ALL {
        let kv = format!("write_mode={}", wmode.name());
        let summary = launch(&cfg(&[kv.as_str(), "np=2", "nc=2", "ns=4"]), None).run();
        assert!(summary.records_produced > 0, "{}: progress", wmode.name());
        assert!(summary.records_consumed > 0, "{}: consumers fed", wmode.name());
        assert!(summary.writers.appends_acked > 0, "{}: acks accounted", wmode.name());
        assert!(summary.writers.mean_append_ns() > 0, "{}: latency measured", wmode.name());
        assert!(summary.writers.threads > 0, "{}: threads accounted", wmode.name());
        assert_eq!(summary.writers.extra(WriteStatKey::Errors), 0, "{}", wmode.name());
        assert_eq!(
            summary.report.gauge("writer_threads"),
            Some(summary.writers.threads as f64)
        );
    }
}

#[test]
fn pipelined_writer_outpaces_sync_on_the_ingestion_workload() {
    // Fig. 3 shape: small chunks make the sync round-trip the bottleneck;
    // overlapping appends must raise ingestion throughput.
    let sync = launch(&cfg(&["write_mode=sync", "np=2", "nc=1", "ns=8", "cs=2KiB"]), None).run();
    let pipe = launch(
        &cfg(&["write_mode=pipelined", "write_inflight=8", "np=2", "nc=1", "ns=8", "cs=2KiB"]),
        None,
    )
    .run();
    assert!(
        pipe.records_produced as f64 > sync.records_produced as f64 * 1.2,
        "pipelining must overlap round-trips: sync {} vs pipelined {}",
        sync.records_produced,
        pipe.records_produced
    );
}

#[test]
fn write_modes_deliver_identical_bounded_totals() {
    // The acceptance gate: on a bounded ingestion workload every write
    // mode delivers exactly the same records (no loss, no duplication),
    // and the consumers drain all of them.
    let mut totals = Vec::new();
    for wmode in WriteMode::ALL {
        let kv = format!("write_mode={}", wmode.name());
        let mut c = cfg(&[kv.as_str(), "mode=pull", "np=2", "nc=2", "ns=4", "cs=4KiB"]);
        c.corpus_records = 20_000; // per producer
        c.duration_secs = 30; // long enough to drain after producers stop
        let summary = launch(&c, None).run();
        assert_eq!(
            summary.records_produced,
            2 * 20_000,
            "{}: bounded producers send the full budget",
            wmode.name()
        );
        assert_eq!(
            summary.records_consumed, summary.records_produced,
            "{}: consumers drain the bounded stream",
            wmode.name()
        );
        totals.push(summary.records_produced);
    }
    assert!(totals.windows(2).all(|w| w[0] == w[1]), "identical across modes: {totals:?}");
}

#[test]
fn sharedmem_writer_keeps_payload_off_the_wire() {
    let sync = launch(&cfg(&["write_mode=sync", "np=2", "nc=2", "ns=4"]), None).run();
    let shm = launch(&cfg(&["write_mode=sharedmem", "np=2", "nc=2", "ns=4"]), None).run();
    let sync_wire = sync.report.gauge("cross_node_bytes").unwrap();
    let shm_wire = shm.report.gauge("cross_node_bytes").unwrap();
    assert!(
        shm_wire < sync_wire * 0.1,
        "colocated producers must not ship payloads cross-node: {shm_wire} vs {sync_wire}"
    );
    assert!(shm.writers.extra(WriteStatKey::ObjectsSealed) > 0);
    assert_eq!(shm.writers.extra(WriteStatKey::Subscribed), 2, "both producers registered");
}

#[test]
fn sharedmem_write_combines_with_push_sources() {
    // Shared-memory ingestion and the read-side push subscription share
    // the plasma store and the broker: both directions must make progress.
    let summary =
        launch(&cfg(&["write_mode=sharedmem", "mode=push", "np=2", "nc=2", "ns=4"]), None).run();
    assert!(summary.records_produced > 0);
    assert!(summary.objects_filled > 0, "read-side push objects still flow");
    assert!(summary.records_consumed > 0);
}

#[test]
fn replicated_sharedmem_appends_still_ack() {
    let summary = launch(
        &cfg(&["write_mode=sharedmem", "np=2", "nc=2", "ns=4", "replication=2"]),
        None,
    )
    .run();
    assert!(summary.records_produced > 0, "seals survive the backup round-trip");
    assert!(summary.writers.mean_append_ns() > 0);
}

#[test]
fn consumers_track_producers() {
    let summary = launch(&cfg(&["mode=pull", "np=2", "nc=2", "ns=4"]), None).run();
    // consumption can lag production, never exceed it
    assert!(summary.records_consumed <= summary.records_produced);
    // The paper's own Fig. 4 finding: "in most configurations, consumers
    // fail to keep up with the producers' rate" — so only a weak lower
    // bound holds in general.
    assert!(
        summary.records_consumed as f64 >= summary.records_produced as f64 * 0.2,
        "consumers make progress: {} vs {}",
        summary.records_consumed,
        summary.records_produced
    );
}

#[test]
fn replication_lowers_ingest_throughput() {
    let r1 = launch(&cfg(&["mode=pull", "np=4", "cs=4KiB", "replication=1"]), None).run();
    let r2 = launch(&cfg(&["mode=pull", "np=4", "cs=4KiB", "replication=2"]), None).run();
    assert!(
        r2.report.producers.p50 < r1.report.producers.p50 * 0.95,
        "paper Fig. 3 shape: replication costs ingest ({} vs {})",
        r2.report.producers.p50,
        r1.report.producers.p50
    );
}

#[test]
fn wordcount_pipeline_counts_tokens() {
    let summary = launch(
        &cfg(&["mode=pull", "workload=wordcount", "recs=2048", "cs=16KiB", "np=1", "nc=2", "ns=4"]),
        None,
    )
    .run();
    // consumer tuples are tokens: >> records
    assert!(
        summary.report.consumers.p50 > summary.report.producers.p50,
        "tokens/s ({}) outnumber records/s ({})",
        summary.report.consumers.p50,
        summary.report.producers.p50
    );
}

#[test]
fn windowed_wordcount_fires_windows() {
    let mut c = cfg(&[
        "mode=push", "workload=wwc", "recs=2048", "cs=16KiB", "np=1", "nc=1", "ns=2",
    ]);
    c.duration_secs = 12;
    let summary = launch(&c, None).run();
    // 12s run, 5s window sliding 1s: several fires per aggregator task
    assert!(summary.windows_fired >= 7, "windows fired: {}", summary.windows_fired);
}

#[test]
fn broker_gauges_exported() {
    let summary = launch(&cfg(&["mode=push", "np=4"]), None).run();
    assert!(summary.report.gauge("broker.dispatcher_util").is_some());
    assert!(summary.report.gauge("broker.worker_util").is_some());
    assert!(summary.report.gauge("broker.push_util").unwrap() > 0.0);
}

// ---------------------------------------------------------------------------
// Checkpoint & recovery (the exactly-once acceptance gate)
// ---------------------------------------------------------------------------

#[test]
fn checkpointing_completes_epochs_and_commits() {
    let summary = launch(
        &cfg(&["mode=pull", "np=2", "nc=2", "ns=4", "checkpoint_interval_ms=200"]),
        None,
    )
    .run();
    // 5 s run at 200 ms intervals: epochs align fast on the sim plane.
    assert!(
        summary.checkpoints.epochs_completed >= 10,
        "epochs completed: {:?}",
        summary.checkpoints
    );
    assert_eq!(summary.checkpoints.recoveries, 0);
    assert!(summary.checkpoints.commits_acked > summary.checkpoints.epochs_completed,
        "genesis + one commit per epoch");
    assert!(summary.report.gauge("checkpoint.epochs").unwrap() >= 10.0);
    assert!(summary.records_consumed > 0, "checkpointing must not stall the stream");
}

#[test]
fn checkpointing_overhead_is_bounded() {
    let plain = launch(&cfg(&["mode=push", "np=2", "nc=2", "ns=4"]), None).run();
    let ckpt = launch(
        &cfg(&["mode=push", "np=2", "nc=2", "ns=4", "checkpoint_interval_ms=200"]),
        None,
    )
    .run();
    assert!(ckpt.checkpoints.epochs_completed >= 10);
    // Barrier alignment briefly pauses the push consume loop; the cost
    // must stay a modest fraction of throughput.
    assert!(
        ckpt.records_consumed as f64 > plain.records_consumed as f64 * 0.7,
        "checkpoint overhead out of bounds: {} vs {}",
        ckpt.records_consumed,
        plain.records_consumed
    );
}

/// The acceptance invariant: for a fixed seed and a bounded stream, a run
/// with an injected mid-run failure recovers from the last checkpoint and
/// reports totals identical to the fault-free run — for every source mode
/// and both fault kinds (a killed worker task and a killed source).
#[test]
fn exactly_once_totals_across_faults() {
    for mode in crate::config::SourceMode::ALL {
        let mk = |fault_kind: Option<&str>| {
            let mode_kv = format!("mode={}", mode.name());
            let mut c = cfg(&[mode_kv.as_str(), "np=2", "nc=2", "ns=4", "cs=4KiB"]);
            c.checkpoint_interval_ms = 200;
            c.corpus_records = 15_000; // per producer: bounded, fully drainable
            c.duration_secs = 30;
            if let Some(kind) = fault_kind {
                c.fault_at_secs = 2;
                c.fault_kind = crate::config::FaultKind::parse(kind).unwrap();
            }
            c
        };
        let clean = launch(&mk(None), None).run();
        assert_eq!(
            clean.records_consumed, clean.records_produced,
            "{}: the fault-free run drains the bounded stream",
            mode.name()
        );
        for kind in ["worker", "source"] {
            let faulted = launch(&mk(Some(kind)), None).run();
            assert!(
                faulted.checkpoints.recoveries >= 1,
                "{}/{kind}: the fault was detected and recovered",
                mode.name()
            );
            assert_eq!(
                faulted.records_produced,
                clean.records_produced,
                "{}/{kind}: producers unaffected",
                mode.name()
            );
            assert_eq!(
                faulted.records_consumed,
                clean.records_consumed,
                "{}/{kind}: exactly-once — no loss, no duplication",
                mode.name()
            );
            assert!(
                faulted.checkpoints.last_recovery_ns > 0,
                "{}/{kind}: recovery time measured",
                mode.name()
            );
        }
    }
}

#[test]
fn exactly_once_windowed_totals_across_a_fault() {
    // Windowed word count: the keyed/windowed operator state snapshots
    // must roll back consistently with the source cursors, so the
    // aggregate windowed tuple total (= tokens) matches the clean run.
    let mk = |fault: bool| {
        let mut c = cfg(&[
            "mode=push", "workload=wwc", "recs=2048", "cs=16KiB", "np=1", "nc=1", "ns=2",
        ]);
        c.checkpoint_interval_ms = 200;
        c.corpus_records = 5_000;
        c.duration_secs = 30;
        if fault {
            c.fault_at_secs = 3;
            c.fault_kind = crate::config::FaultKind::Worker;
        }
        c
    };
    let clean = launch(&mk(false), None).run();
    let faulted = launch(&mk(true), None).run();
    assert!(clean.windowed_tuples > 0);
    assert_eq!(faulted.records_consumed, clean.records_consumed);
    assert_eq!(
        faulted.windowed_tuples, clean.windowed_tuples,
        "windowed totals identical under recovery"
    );
    assert!(faulted.checkpoints.recoveries >= 1);
}

#[test]
fn replay_is_accounted() {
    // A source fault while data still flows forces a rollback with a
    // non-trivial replay span; the replayed records surface in the
    // checkpoint stats and gauges. Producers are throttled (100 us per
    // record) so the bounded stream is still mid-flight at the fault.
    let mut c = cfg(&["mode=pull", "np=2", "nc=2", "ns=4", "cost.producer_record_ns=100000"]);
    c.checkpoint_interval_ms = 500; // coarse epochs -> a visible replay span
    c.corpus_records = 50_000;
    c.duration_secs = 30;
    c.fault_at_secs = 2;
    c.fault_kind = crate::config::FaultKind::Source;
    let summary = launch(&c, None).run();
    assert_eq!(summary.records_consumed, summary.records_produced, "still drains");
    assert!(
        summary.checkpoints.records_replayed > 0,
        "a rollback re-reads the span since the last checkpoint: {:?}",
        summary.checkpoints
    );
    assert_eq!(
        summary.report.gauge("checkpoint.replayed_records"),
        Some(summary.checkpoints.records_replayed as f64)
    );
}

#[test]
fn deterministic_across_runs() {
    let a = launch(&cfg(&["mode=push", "np=2", "nc=2"]), None).run();
    let b = launch(&cfg(&["mode=push", "np=2", "nc=2"]), None).run();
    assert_eq!(a.records_produced, b.records_produced);
    assert_eq!(a.records_consumed, b.records_consumed);
    assert_eq!(a.objects_filled, b.objects_filled);
}

#[test]
fn seed_changes_trajectory_slightly_but_not_wildly() {
    let mut c1 = cfg(&["mode=pull", "np=2", "nc=2"]);
    c1.seed = 1;
    let mut c2 = cfg(&["mode=pull", "np=2", "nc=2"]);
    c2.seed = 2;
    let a = launch(&c1, None).run();
    let b = launch(&c2, None).run();
    // sim-plane generators are deterministic in structure; totals should
    // be in the same ballpark across seeds
    let ratio = a.records_produced as f64 / b.records_produced as f64;
    assert!((0.8..1.2).contains(&ratio), "seed sensitivity too high: {ratio}");
}
