//! The metrics blackboard shared by all actors.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::sim::{Time, SECOND};

/// Throughput series class. Matches what the paper plots per figure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Class {
    /// Records acknowledged to producers (appended).
    ProducerRecords,
    /// Tuples processed by consumers (the RTLogger counts).
    ConsumerTuples,
    /// Bytes appended (broker ingest volume).
    ProducerBytes,
    /// Bytes served to consumers (pull replies + filled objects).
    ConsumerBytes,
    /// Pull RPCs issued (resource accounting; push issues ~0).
    PullRpcs,
    /// Shared objects filled (push path volume).
    ObjectsFilled,
}

impl Class {
    pub const ALL: [Class; 6] = [
        Class::ProducerRecords,
        Class::ConsumerTuples,
        Class::ProducerBytes,
        Class::ConsumerBytes,
        Class::PullRpcs,
        Class::ObjectsFilled,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Class::ProducerRecords => "producer_records",
            Class::ConsumerTuples => "consumer_tuples",
            Class::ProducerBytes => "producer_bytes",
            Class::ConsumerBytes => "consumer_bytes",
            Class::PullRpcs => "pull_rpcs",
            Class::ObjectsFilled => "objects_filled",
        }
    }
}

/// Per-(class, entity) counters bucketed by virtual second, plus end-of-run
/// gauges (utilisation, thread counts) set by the launcher.
///
/// The hub also hosts the observability plane: [`crate::obs::Tracer`]
/// rides along as a public field, so every actor that already holds a
/// [`SharedMetrics`] handle can trace spans without any rewiring. The
/// tracer is inert (all calls gated on [`crate::obs::Tracer::enabled`])
/// until the launcher configures `trace_sample_permille > 0`.
#[derive(Debug, Default)]
pub struct MetricsHub {
    // (class, entity) -> per-second counts, indexed by second.
    series: HashMap<(Class, usize), Vec<u64>>,
    gauges: Vec<(String, f64)>,
    /// The latency-tracing plane (spans, histograms, event sink).
    pub tracer: crate::obs::Tracer,
}

/// Shared handle actors hold.
pub type SharedMetrics = Rc<RefCell<MetricsHub>>;

impl MetricsHub {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn shared() -> SharedMetrics {
        Rc::new(RefCell::new(Self::new()))
    }

    /// Add `n` to the (class, entity) counter of the current second.
    pub fn record(&mut self, class: Class, entity: usize, now: Time, n: u64) {
        let sec = (now / SECOND) as usize;
        let buckets = self.series.entry((class, entity)).or_default();
        if buckets.len() <= sec {
            buckets.resize(sec + 1, 0);
        }
        buckets[sec] += n;
    }

    /// Sum of a class across entities per second, over `[warmup, horizon)`.
    /// Seconds with no activity count as zero — an idle system *is* a
    /// zero-throughput system, and the paper's percentile must see that.
    pub fn per_second_totals(&self, class: Class, warmup_s: u64, horizon_s: u64) -> Vec<u64> {
        let lo = warmup_s as usize;
        let hi = horizon_s as usize;
        let mut totals = vec![0u64; hi.saturating_sub(lo)];
        for ((c, _), buckets) in &self.series {
            if *c != class {
                continue;
            }
            for (sec, &v) in buckets.iter().enumerate() {
                if sec >= lo && sec < hi {
                    totals[sec - lo] += v;
                }
            }
        }
        totals
    }

    /// Lifetime total for a class.
    pub fn total(&self, class: Class) -> u64 {
        self.series
            .iter()
            .filter(|((c, _), _)| *c == class)
            .map(|(_, b)| b.iter().sum::<u64>())
            .sum()
    }

    /// Lifetime total for one entity of a class.
    pub fn total_for(&self, class: Class, entity: usize) -> u64 {
        self.series
            .get(&(class, entity))
            .map(|b| b.iter().sum())
            .unwrap_or(0)
    }

    /// Entities that reported a class (e.g. how many consumers made progress).
    pub fn entities(&self, class: Class) -> usize {
        self.series.keys().filter(|(c, _)| *c == class).count()
    }

    /// Record an end-of-run gauge (utilisation, thread count, ...).
    pub fn set_gauge(&mut self, name: impl Into<String>, value: f64) {
        self.gauges.push((name.into(), value));
    }

    pub fn gauges(&self) -> &[(String, f64)] {
        &self.gauges
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().rev().find(|(n, _)| n == name).map(|&(_, v)| v)
    }
}
