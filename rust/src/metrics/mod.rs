//! Per-virtual-second throughput recording and the paper's p50 statistic.
//!
//! The paper: "We run each experiment for 60 to 180 seconds while we collect
//! producer and consumer throughput metrics (records/tuples every second).
//! We plot 50-percentile aggregated throughput per second" (§V-C). The hub
//! buckets every counter increment into its virtual second; a report then
//! sums across entities of a class per second and takes the median second.

mod hub;
mod report;
#[cfg(test)]
mod tests;

pub use hub::{Class, MetricsHub, SharedMetrics};
pub use report::{percentile, ExperimentReport, SeriesStat};
