//! Unit tests: second bucketing, aggregation, percentiles.

use super::*;
use crate::sim::SECOND;

#[test]
fn record_buckets_by_virtual_second() {
    let mut hub = MetricsHub::new();
    hub.record(Class::ProducerRecords, 0, 0, 10);
    hub.record(Class::ProducerRecords, 0, SECOND - 1, 5);
    hub.record(Class::ProducerRecords, 0, SECOND, 7);
    let totals = hub.per_second_totals(Class::ProducerRecords, 0, 2);
    assert_eq!(totals, vec![15, 7]);
}

#[test]
fn totals_sum_across_entities() {
    let mut hub = MetricsHub::new();
    hub.record(Class::ConsumerTuples, 1, 0, 100);
    hub.record(Class::ConsumerTuples, 2, 0, 200);
    hub.record(Class::ConsumerTuples, 2, SECOND, 50);
    assert_eq!(hub.per_second_totals(Class::ConsumerTuples, 0, 2), vec![300, 50]);
    assert_eq!(hub.total(Class::ConsumerTuples), 350);
    assert_eq!(hub.total_for(Class::ConsumerTuples, 2), 250);
    assert_eq!(hub.entities(Class::ConsumerTuples), 2);
}

#[test]
fn warmup_seconds_excluded() {
    let mut hub = MetricsHub::new();
    for sec in 0..10u64 {
        hub.record(Class::ProducerRecords, 0, sec * SECOND, sec);
    }
    let totals = hub.per_second_totals(Class::ProducerRecords, 5, 10);
    assert_eq!(totals, vec![5, 6, 7, 8, 9]);
}

#[test]
fn idle_seconds_count_as_zero() {
    let mut hub = MetricsHub::new();
    hub.record(Class::ProducerRecords, 0, 0, 4);
    // horizon 5s but only second 0 active: the series still has 5 entries
    let totals = hub.per_second_totals(Class::ProducerRecords, 0, 5);
    assert_eq!(totals, vec![4, 0, 0, 0, 0]);
}

#[test]
fn classes_do_not_mix() {
    let mut hub = MetricsHub::new();
    hub.record(Class::ProducerRecords, 0, 0, 1);
    hub.record(Class::ConsumerTuples, 0, 0, 2);
    assert_eq!(hub.total(Class::ProducerRecords), 1);
    assert_eq!(hub.total(Class::ConsumerTuples), 2);
}

#[test]
fn gauges_last_write_wins() {
    let mut hub = MetricsHub::new();
    hub.set_gauge("dispatcher_util", 0.5);
    hub.set_gauge("dispatcher_util", 0.9);
    assert_eq!(hub.gauge("dispatcher_util"), Some(0.9));
    assert_eq!(hub.gauge("missing"), None);
}

mod stats {
    use super::*;

    #[test]
    fn p50_of_odd_series_is_median() {
        let stat = SeriesStat::from_series(&[10, 30, 20]);
        assert_eq!(stat.p50, 20.0);
        assert_eq!(stat.seconds, 3);
    }

    #[test]
    fn empty_series_is_zero() {
        let stat = SeriesStat::from_series(&[]);
        assert_eq!(stat.p50, 0.0);
        assert_eq!(stat.seconds, 0);
    }

    #[test]
    fn percentile_bounds() {
        let sorted: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&sorted, 0.0), 1.0);
        assert_eq!(percentile(&sorted, 100.0), 100.0);
        let p50 = percentile(&sorted, 50.0);
        assert!((50.0..=51.0).contains(&p50));
    }

    #[test]
    fn constant_series() {
        let stat = SeriesStat::from_series(&[7; 60]);
        assert_eq!(stat.p50, 7.0);
        assert_eq!(stat.mean, 7.0);
        assert_eq!(stat.p10, 7.0);
        assert_eq!(stat.p90, 7.0);
        assert_eq!(stat.p95, 7.0);
        assert_eq!(stat.p99, 7.0);
        assert_eq!(stat.p999, 7.0);
    }

    #[test]
    fn tail_percentiles_pick_the_worst_seconds() {
        // 0..1000: nearest ranks are exact, and the tail orders correctly.
        let series: Vec<u64> = (0..1000).collect();
        let stat = SeriesStat::from_series(&series);
        assert_eq!(stat.p50, 500.0);
        assert_eq!(stat.p90, 899.0);
        assert_eq!(stat.p95, 949.0);
        assert_eq!(stat.p99, 989.0);
        assert_eq!(stat.p999, 998.0);
        // On a short series the tail collapses onto the max — the rank
        // math, not a special case.
        let stat = SeriesStat::from_series(&[10, 30, 20]);
        assert_eq!(stat.p95, 30.0);
        assert_eq!(stat.p99, 30.0);
        assert_eq!(stat.p999, 30.0);
    }

    #[test]
    fn report_from_hub() {
        let mut hub = MetricsHub::new();
        for sec in 0..10u64 {
            hub.record(Class::ProducerRecords, 0, sec * SECOND, 1_000_000);
            hub.record(Class::ConsumerTuples, 0, sec * SECOND, 500_000);
        }
        hub.set_gauge("source_threads", 2.0);
        let rep = ExperimentReport::from_hub("t", &hub, 2, 10);
        assert_eq!(rep.producers.p50, 1_000_000.0);
        assert_eq!(rep.consumers.p50, 500_000.0);
        assert!((rep.cluster_mrec_s() - 1.5).abs() < 1e-9);
        assert_eq!(rep.gauge("source_threads"), Some(2.0));
        assert!(rep.row().contains("prod(p50)"));
    }
}
