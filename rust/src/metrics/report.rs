//! Experiment reports: the rows each figure of the paper plots.

use super::{Class, MetricsHub};

/// Summary statistics of one per-second series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeriesStat {
    /// The paper's statistic: median per-second aggregated throughput.
    pub p50: f64,
    pub p10: f64,
    pub p90: f64,
    pub mean: f64,
    pub seconds: usize,
}

impl SeriesStat {
    pub fn from_series(series: &[u64]) -> Self {
        if series.is_empty() {
            return SeriesStat { p50: 0.0, p10: 0.0, p90: 0.0, mean: 0.0, seconds: 0 };
        }
        let mut sorted: Vec<u64> = series.to_vec();
        sorted.sort_unstable();
        let mean = sorted.iter().sum::<u64>() as f64 / sorted.len() as f64;
        SeriesStat {
            p50: percentile(&sorted, 50.0),
            p10: percentile(&sorted, 10.0),
            p90: percentile(&sorted, 90.0),
            mean,
            seconds: series.len(),
        }
    }
}

/// Nearest-rank percentile of an ascending-sorted series.
pub fn percentile(sorted: &[u64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((pct / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)] as f64
}

/// Everything one experiment run reports — one row of a figure's series.
#[derive(Debug, Clone)]
pub struct ExperimentReport {
    pub name: String,
    /// Producer records/s (aggregated, p50 across seconds).
    pub producers: SeriesStat,
    /// Consumer tuples/s (aggregated, p50 across seconds).
    pub consumers: SeriesStat,
    pub producer_bytes: SeriesStat,
    pub consumer_bytes: SeriesStat,
    /// Pull RPCs issued per second (resource pressure on the dispatcher).
    pub pull_rpcs: SeriesStat,
    /// Shared objects filled per second (push-path volume).
    pub objects_filled: SeriesStat,
    /// End-of-run gauges (utilisations, thread counts).
    pub gauges: Vec<(String, f64)>,
}

impl ExperimentReport {
    /// Build from the hub over `[warmup, horizon)` seconds.
    pub fn from_hub(name: &str, hub: &MetricsHub, warmup_s: u64, horizon_s: u64) -> Self {
        let stat = |class: Class| {
            SeriesStat::from_series(&hub.per_second_totals(class, warmup_s, horizon_s))
        };
        ExperimentReport {
            name: name.to_string(),
            producers: stat(Class::ProducerRecords),
            consumers: stat(Class::ConsumerTuples),
            producer_bytes: stat(Class::ProducerBytes),
            consumer_bytes: stat(Class::ConsumerBytes),
            pull_rpcs: stat(Class::PullRpcs),
            objects_filled: stat(Class::ObjectsFilled),
            gauges: hub.gauges().to_vec(),
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().rev().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// Cluster throughput the paper plots: producers + consumers, Mrec/s.
    pub fn cluster_mrec_s(&self) -> f64 {
        (self.producers.p50 + self.consumers.p50) / 1e6
    }

    /// One aligned table row (figure harnesses print these).
    pub fn row(&self) -> String {
        format!(
            "{:<34} prod(p50) {:>9.3} Mrec/s  cons(p50) {:>9.3} Mtup/s  cluster {:>9.3} M/s  pullRPC/s {:>9.0}  objs/s {:>7.0}",
            self.name,
            self.producers.p50 / 1e6,
            self.consumers.p50 / 1e6,
            self.cluster_mrec_s(),
            self.pull_rpcs.p50,
            self.objects_filled.p50,
        )
    }
}
