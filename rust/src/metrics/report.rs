//! Experiment reports: the rows each figure of the paper plots.

use super::{Class, MetricsHub};

/// Summary statistics of one per-second series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeriesStat {
    /// The paper's statistic: median per-second aggregated throughput.
    pub p50: f64,
    pub p10: f64,
    pub p90: f64,
    /// Tail percentiles (nearest rank; on short series they collapse onto
    /// the max second — the rank math, not an estimate).
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub mean: f64,
    pub seconds: usize,
}

impl SeriesStat {
    pub fn from_series(series: &[u64]) -> Self {
        Self::from_series_with(series, &mut Vec::new())
    }

    /// [`SeriesStat::from_series`] with a caller-owned scratch buffer.
    ///
    /// This runs per gauge at every report, so it selects the six ranks
    /// (p10/p50/p90/p95/p99/p999) with `select_nth_unstable` (expected
    /// O(n) each) on a reused scratch copy instead of `to_vec()` + full
    /// sort per call. Selections run in ascending rank order on narrowing
    /// subslices: after selecting rank `r`, everything at `r..` is ≥ the
    /// pivot, so the next (higher) rank is found inside `scratch[r..]` —
    /// each pass touches less data, and duplicate nearest ranks (common
    /// for the tail on short series) reuse the previous selection.
    pub fn from_series_with(series: &[u64], scratch: &mut Vec<u64>) -> Self {
        if series.is_empty() {
            return SeriesStat {
                p50: 0.0,
                p10: 0.0,
                p90: 0.0,
                p95: 0.0,
                p99: 0.0,
                p999: 0.0,
                mean: 0.0,
                seconds: 0,
            };
        }
        let mean = series.iter().sum::<u64>() as f64 / series.len() as f64;
        scratch.clear();
        scratch.extend_from_slice(series);
        let mut ranks = [
            (nearest_rank(series.len(), 10.0), 0u64),
            (nearest_rank(series.len(), 50.0), 0u64),
            (nearest_rank(series.len(), 90.0), 0u64),
            (nearest_rank(series.len(), 95.0), 0u64),
            (nearest_rank(series.len(), 99.0), 0u64),
            (nearest_rank(series.len(), 99.9), 0u64),
        ];
        let mut base = 0usize; // scratch[..base] already below previous rank
        let mut prev_rank = 0usize;
        let mut prev_value = 0u64;
        for (rank, value) in ranks.iter_mut() {
            if base > 0 && *rank == prev_rank {
                *value = prev_value; // same nearest rank: same element
                continue;
            }
            let (_, &mut v, _) = scratch[base..].select_nth_unstable(*rank - base);
            *value = v;
            base = *rank;
            prev_rank = *rank;
            prev_value = v;
        }
        SeriesStat {
            p10: ranks[0].1 as f64,
            p50: ranks[1].1 as f64,
            p90: ranks[2].1 as f64,
            p95: ranks[3].1 as f64,
            p99: ranks[4].1 as f64,
            p999: ranks[5].1 as f64,
            mean,
            seconds: series.len(),
        }
    }
}

/// Nearest rank of `pct` in a series of `len` (len > 0).
fn nearest_rank(len: usize, pct: f64) -> usize {
    let rank = ((pct / 100.0) * (len as f64 - 1.0)).round() as usize;
    rank.min(len - 1)
}

/// Nearest-rank percentile of an ascending-sorted series.
pub fn percentile(sorted: &[u64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[nearest_rank(sorted.len(), pct)] as f64
}

/// Everything one experiment run reports — one row of a figure's series.
#[derive(Debug, Clone)]
pub struct ExperimentReport {
    pub name: String,
    /// Producer records/s (aggregated, p50 across seconds).
    pub producers: SeriesStat,
    /// Consumer tuples/s (aggregated, p50 across seconds).
    pub consumers: SeriesStat,
    pub producer_bytes: SeriesStat,
    pub consumer_bytes: SeriesStat,
    /// Pull RPCs issued per second (resource pressure on the dispatcher).
    pub pull_rpcs: SeriesStat,
    /// Shared objects filled per second (push-path volume).
    pub objects_filled: SeriesStat,
    /// End-of-run gauges (utilisations, thread counts).
    pub gauges: Vec<(String, f64)>,
}

impl ExperimentReport {
    /// Build from the hub over `[warmup, horizon)` seconds.
    pub fn from_hub(name: &str, hub: &MetricsHub, warmup_s: u64, horizon_s: u64) -> Self {
        // One scratch buffer serves all six series selections.
        let scratch = std::cell::RefCell::new(Vec::new());
        let stat = |class: Class| {
            SeriesStat::from_series_with(
                &hub.per_second_totals(class, warmup_s, horizon_s),
                &mut scratch.borrow_mut(),
            )
        };
        ExperimentReport {
            name: name.to_string(),
            producers: stat(Class::ProducerRecords),
            consumers: stat(Class::ConsumerTuples),
            producer_bytes: stat(Class::ProducerBytes),
            consumer_bytes: stat(Class::ConsumerBytes),
            pull_rpcs: stat(Class::PullRpcs),
            objects_filled: stat(Class::ObjectsFilled),
            gauges: hub.gauges().to_vec(),
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().rev().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// Cluster throughput the paper plots: producers + consumers, Mrec/s.
    pub fn cluster_mrec_s(&self) -> f64 {
        (self.producers.p50 + self.consumers.p50) / 1e6
    }

    /// One aligned table row (figure harnesses print these). Alongside the
    /// paper's p50 statistic the row carries the consumer tail
    /// (p95/p99/p999 per-second throughput) so a run whose median looks
    /// healthy but whose worst seconds crater is visible at a glance.
    pub fn row(&self) -> String {
        format!(
            "{:<34} prod(p50) {:>9.3} Mrec/s  cons(p50) {:>9.3} Mtup/s  cluster {:>9.3} M/s  cons(p95/p99/p999) {:>7.3}/{:>7.3}/{:>7.3}  pullRPC/s {:>9.0}  objs/s {:>7.0}",
            self.name,
            self.producers.p50 / 1e6,
            self.consumers.p50 / 1e6,
            self.cluster_mrec_s(),
            self.consumers.p95 / 1e6,
            self.consumers.p99 / 1e6,
            self.consumers.p999 / 1e6,
            self.pull_rpcs.p50,
            self.objects_filled.p50,
        )
    }
}
