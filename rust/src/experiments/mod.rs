//! The experiment catalog: one driver per table/figure of the paper's
//! evaluation (§V), plus the ablations DESIGN.md §4 calls out.
//!
//! Each figure is a [`FigureSpec`]: a list of labelled [`ExperimentConfig`]
//! rows whose p50 throughputs are the series the paper plots. The bench
//! harnesses (`rust/benches/figN_*.rs`) and the CLI (`zettastream bench`)
//! both run these specs and print the rows.

pub mod chaos;
pub mod hotpath;
pub mod latency;
#[cfg(test)]
mod tests;

use crate::cluster::{launch, RunSummary};
use crate::config::{ExperimentConfig, FaultKind, SourceMode, StoreMode, Workload, WriteMode};

/// Chunk sizes the paper sweeps (KiB): "values=1,2,4,8,16,32,64,128".
pub const CHUNK_SIZES_KIB: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

/// One figure/table to regenerate.
pub struct FigureSpec {
    /// `fig3` ... `fig9`, `ablation-*`.
    pub id: &'static str,
    pub title: &'static str,
    /// What the paper's version of this figure shows (the shape to check).
    pub expectation: &'static str,
    pub rows: Vec<(String, ExperimentConfig)>,
}

fn base(duration: u64) -> ExperimentConfig {
    ExperimentConfig {
        duration_secs: duration,
        warmup_secs: duration / 6,
        ..Default::default()
    }
}

/// Fig. 3 — ingestion-only: Np ∈ {2,4,8}, Replication ∈ {1,2}, sweep CS.
/// "R1Prods2 ... two producers ... one single copy; R2Prods8 ... eight
/// producers with replication factor two."
pub fn fig3(duration: u64, chunk_sizes: &[usize]) -> FigureSpec {
    let mut rows = Vec::new();
    for &np in &[2usize, 4, 8] {
        for &repl in &[1usize, 2] {
            for &cs in chunk_sizes {
                let mut c = base(duration);
                c.np = np;
                c.nc = 1; // consumers idle: give the single consumer all partitions
                c.ns = 8;
                c.nmap = 1;
                c.replication = repl;
                c.producer_chunk = cs * 1024;
                c.consumer_chunk = 128 * 1024;
                c.record_size = 100;
                c.broker_cores = 16;
                c.mode = SourceMode::NativePull;
                // Ingestion benchmark: measure producers only. A single
                // idle-ish native consumer stands in for "no consumers".
                c.pull_timeout_us = 1_000_000;
                c.workload = Workload::Count;
                c.name = format!("R{repl}Prods{np}/cs{cs}KiB");
                rows.push((c.name.clone(), c));
            }
        }
    }
    FigureSpec {
        id: "fig3",
        title: "Ingestion benchmark: producers only, 8 partitions, RecS=100B",
        expectation: "throughput grows with CS and Np; Replication=2 visibly lower",
        rows,
    }
}

/// Helper for the concurrent producer/consumer figures: one row per
/// (mode, Np=Nc, producer CS).
#[allow(clippy::too_many_arguments)]
fn pc_rows(
    duration: u64,
    modes: &[SourceMode],
    npc: &[usize],
    chunk_sizes: &[usize],
    ns: usize,
    nbc: usize,
    workload: Workload,
    replication: usize,
    consumer_chunk: ConsumerChunk,
) -> Vec<(String, ExperimentConfig)> {
    let mut rows = Vec::new();
    for &mode in modes {
        for &n in npc {
            for &cs in chunk_sizes {
                let mut c = base(duration);
                c.np = n;
                c.nc = n.min(ns);
                c.nmap = 8;
                c.ns = ns;
                c.replication = replication;
                c.producer_chunk = cs * 1024;
                c.consumer_chunk = match consumer_chunk {
                    ConsumerChunk::Fixed128KiB => 128 * 1024,
                    ConsumerChunk::EqualToProducer => cs * 1024,
                    ConsumerChunk::EightTimesProducer => 8 * cs * 1024,
                };
                c.record_size = 100;
                c.broker_cores = nbc;
                c.worker_slots = 16;
                c.mode = mode;
                c.workload = workload;
                c.name = format!("{}{}x/cs{}KiB", mode.name(), n, cs);
                rows.push((c.name.clone(), c));
            }
        }
    }
    rows
}

#[derive(Clone, Copy)]
enum ConsumerChunk {
    Fixed128KiB,
    EqualToProducer,
    EightTimesProducer,
}

/// Fig. 4 — iterate + count, 8 partitions, 16-core broker, consumer chunk
/// fixed 128 KiB; producers vs pull vs push at Np=Nc ∈ {2,4,8}.
pub fn fig4(duration: u64, chunk_sizes: &[usize]) -> FigureSpec {
    FigureSpec {
        id: "fig4",
        title: "Iterate+count, Ns=8, NBc=16, consumer CS=128KiB",
        expectation: "push ≥ pull for Nc<=4 with 2 source threads vs 2*Nc; \
                      push does NOT scale to Nc=8 (single push/consume thread); \
                      consumers mostly below producers",
        rows: pc_rows(
            duration,
            &[SourceMode::Pull, SourceMode::Push],
            &[2, 4, 8],
            chunk_sizes,
            8,
            16,
            Workload::Count,
            1,
            ConsumerChunk::Fixed128KiB,
        ),
    }
}

/// Fig. 5 — iterate + count + filter, 8 partitions: pull vs push.
pub fn fig5(duration: u64, chunk_sizes: &[usize]) -> FigureSpec {
    FigureSpec {
        id: "fig5",
        title: "Iterate+count+filter, Ns=8, consumer CS=128KiB",
        expectation: "same shape as fig4 with slightly lower consumer throughput \
                      (filter adds per-record CPU); push@8 lags pull@8",
        rows: pc_rows(
            duration,
            &[SourceMode::Pull, SourceMode::Push],
            &[2, 4, 8],
            chunk_sizes,
            8,
            16,
            Workload::Filter,
            1,
            ConsumerChunk::Fixed128KiB,
        ),
    }
}

/// Fig. 6 — iterate + count + filter with only 4 partitions, up to 4
/// producers/consumers.
pub fn fig6(duration: u64, chunk_sizes: &[usize]) -> FigureSpec {
    FigureSpec {
        id: "fig6",
        title: "Iterate+count+filter, Ns=4, up to 4 producers/consumers",
        expectation: "push slightly higher at small chunks (~+2 Mtup/s), \
                      advantage fades at large chunks",
        rows: pc_rows(
            duration,
            &[SourceMode::Pull, SourceMode::Push],
            &[2, 4],
            chunk_sizes,
            4,
            16,
            Workload::Filter,
            1,
            ConsumerChunk::Fixed128KiB,
        ),
    }
}

/// Fig. 7 — constrained broker: NBc=4, Replication=2, Ns=8, Np=Nc=4,
/// consumer chunk == producer chunk; C++ pull vs Flink pull vs Flink push.
pub fn fig7(duration: u64, chunk_sizes: &[usize]) -> FigureSpec {
    FigureSpec {
        id: "fig7",
        title: "Constrained broker (NBc=4, Replication=2, Np=Nc=4, Ns=8)",
        expectation: "native (C++) pull keeps up with producers; Flink push up to \
                      2x Flink pull; push producers >= pull producers",
        rows: pc_rows(
            duration,
            &[SourceMode::NativePull, SourceMode::Pull, SourceMode::Push],
            &[4],
            chunk_sizes,
            8,
            4,
            Workload::Filter,
            2,
            ConsumerChunk::EqualToProducer,
        ),
    }
}

/// Fig. 8 — small chunks: producer CS ∈ {1,2,4} KiB, consumer CS = 8x,
/// 8-core broker.
pub fn fig8(duration: u64) -> FigureSpec {
    FigureSpec {
        id: "fig8",
        title: "Small chunks (consumer CS = 8x producer CS), NBc=8, Ns=8",
        expectation: "pull pays per-RPC cost on small available batches; push \
                      matches or beats it with fewer resources",
        rows: pc_rows(
            duration,
            &[SourceMode::NativePull, SourceMode::Pull, SourceMode::Push],
            &[4],
            &[1, 2, 4],
            8,
            8,
            Workload::Count,
            1,
            ConsumerChunk::EightTimesProducer,
        ),
    }
}

/// Fig. 9 — Wikipedia (windowed) word count, 4 partitions, Nc ∈ {1,2,4},
/// Nmap=8, 2 KiB records; pull vs push.
pub fn fig9(duration: u64) -> FigureSpec {
    let mut rows = Vec::new();
    for &windowed in &[false, true] {
        for &mode in &[SourceMode::Pull, SourceMode::Push] {
            for &nc in &[1usize, 2, 4] {
                let mut c = base(duration);
                c.np = 4;
                c.nc = nc;
                c.nmap = 8;
                c.ns = 4;
                c.producer_chunk = 16 * 1024;
                c.consumer_chunk = 128 * 1024;
                c.record_size = 2048;
                c.broker_cores = 16;
                c.mode = mode;
                c.workload = if windowed {
                    Workload::WindowedWordCount
                } else {
                    Workload::WordCount
                };
                c.name = format!(
                    "{}{}Cons{}",
                    if windowed { "w" } else { "" },
                    if mode == SourceMode::Push { "FL" } else { "FPL" },
                    nc
                );
                rows.push((c.name.clone(), c));
            }
        }
    }
    FigureSpec {
        id: "fig9",
        title: "Wikipedia (windowed) word count, Ns=4, Nmap=8, RecS=2KiB",
        expectation: "pull ≈ push: the benchmark is CPU-bound in the mappers",
        rows,
    }
}

/// Ablation — the adaptive hybrid source against its two parents on the
/// Fig. 3/4 setup: the count workload, sweeping producer pressure from the
/// unloaded broker (Np=2, 16 cores) to the write-heavy constrained one
/// (Np=8, 4 cores) where Fig. 7 shows pulls starving.
pub fn ablation_hybrid(duration: u64, chunk_sizes: &[usize]) -> FigureSpec {
    let modes = [SourceMode::Pull, SourceMode::Push, SourceMode::Hybrid];
    let mut rows = pc_rows(
        duration,
        &modes,
        &[2],
        chunk_sizes,
        8,
        16,
        Workload::Count,
        1,
        ConsumerChunk::Fixed128KiB,
    );
    rows.extend(pc_rows(
        duration,
        &modes,
        &[8],
        chunk_sizes,
        8,
        4,
        Workload::Count,
        1,
        ConsumerChunk::Fixed128KiB,
    ));
    FigureSpec {
        id: "ablation-hybrid",
        title: "Adaptive hybrid vs pull vs push (count, Np∈{2,8}, NBc∈{16,4})",
        expectation: "hybrid tracks pull on the unloaded broker and converges \
                      to push under write-heavy contention",
        rows,
    }
}

/// Ablation — the three write paths against the read-side modes on the
/// Fig. 3 ingestion workload: Np=4 producers on 8 partitions, RecS=100B,
/// sweeping CS, once on the unloaded 16-core broker and once on the
/// constrained 4-core one where write RPCs and pull reads fight hardest.
/// Reports per-mode ingestion throughput and append round-trip latency
/// (`write_append_latency_us`); `sync` is the pre-refactor §V-A baseline.
pub fn ablation_writepath(duration: u64, chunk_sizes: &[usize]) -> FigureSpec {
    let mut rows = Vec::new();
    for &nbc in &[16usize, 4] {
        for &wmode in &WriteMode::ALL {
            for &smode in &[SourceMode::Pull, SourceMode::Push, SourceMode::Hybrid] {
                for &cs in chunk_sizes {
                    let mut c = base(duration);
                    c.np = 4;
                    c.nc = 4;
                    c.nmap = 8;
                    c.ns = 8;
                    c.producer_chunk = cs * 1024;
                    c.consumer_chunk = 128 * 1024;
                    c.record_size = 100;
                    c.broker_cores = nbc;
                    c.write_mode = wmode;
                    c.mode = smode;
                    c.workload = Workload::Count;
                    c.name =
                        format!("{}+{}-nbc{}/cs{}KiB", wmode.name(), smode.name(), nbc, cs);
                    rows.push((c.name.clone(), c));
                }
            }
        }
    }
    FigureSpec {
        id: "ablation-writepath",
        title: "Write paths (sync/pipelined/sharedmem) x sources (pull/push/hybrid), \
                Fig. 3 ingestion workload",
        expectation: "pipelined raises ingestion over sync (round-trips overlap) at the \
                      cost of append latency under contention; sharedmem keeps latency \
                      low and frees the wire, but its appends still compete on the \
                      worker cores; sync matches the pre-refactor baseline",
        rows,
    }
}

/// Ablation — checkpoint & recovery: the cost of exactly-once across the
/// source/write design space, on the Fig. 4-style count workload. For each
/// (source mode × write mode) cell, three rows: `base` (no checkpointing —
/// the overhead reference), `ckpt` (aligned barriers every 500 ms), and
/// `fault` (checkpointing plus a mid-run worker kill and recovery).
/// Quantifies what the paper never measured: checkpoint overhead, barrier
/// alignment time, and recovery time differ between the pull design
/// (rewind a cursor) and the push/shared-memory design (resubscribe and
/// replay).
pub fn ablation_checkpoint(duration: u64) -> FigureSpec {
    let smodes = [SourceMode::Pull, SourceMode::Push, SourceMode::Hybrid];
    let mut rows = Vec::new();
    for &wmode in &WriteMode::ALL {
        for &smode in &smodes {
            for variant in ["base", "ckpt", "fault"] {
                let mut c = base(duration);
                c.np = 4;
                c.nc = 4;
                c.nmap = 8;
                c.ns = 8;
                c.producer_chunk = 16 * 1024;
                c.consumer_chunk = 128 * 1024;
                c.record_size = 100;
                c.broker_cores = 16;
                c.mode = smode;
                c.write_mode = wmode;
                c.workload = Workload::Count;
                if variant != "base" {
                    c.checkpoint_interval_ms = 500;
                }
                if variant == "fault" {
                    c.fault_at_secs = (duration / 2).max(1);
                    c.fault_kind = FaultKind::Worker;
                }
                c.name = format!("{}+{}-{}", smode.name(), wmode.name(), variant);
                rows.push((c.name.clone(), c));
            }
        }
    }
    FigureSpec {
        id: "ablation-checkpoint",
        title: "Checkpoint & recovery: sources (pull/push/hybrid) x writers \
                (sync/pipelined/sharedmem), count workload",
        expectation: "checkpointing costs a few percent of throughput (barrier \
                      alignment stalls the emit loop); pull recovers by rewinding \
                      cursors while push must resubscribe and replay, so push \
                      recovery/replay is costlier; faulted rows report non-zero \
                      recovery time and replayed records",
        rows,
    }
}

/// Ablation — the storage tier: the durable WAL + sorted-segment backend
/// against the in-memory default, across the whole source × write design
/// space on the Fig. 4-style count workload. Every durable row runs with
/// 1 MiB segments so a short run still seals, flushes and compacts cold
/// files instead of living entirely in the WAL tail. The question the
/// paper leaves open (§VI colocation): what does real log durability cost
/// the pull and push read paths, and does the zero-copy discipline survive
/// the disk hop (cold reads re-enter the spine as shared payloads)?
pub fn ablation_store(duration: u64) -> FigureSpec {
    let mut rows = Vec::new();
    for &store in &StoreMode::ALL {
        for &wmode in &WriteMode::ALL {
            for &smode in &SourceMode::ALL {
                let mut c = base(duration);
                c.np = 4;
                c.nc = 4;
                c.nmap = 8;
                c.ns = 8;
                c.producer_chunk = 16 * 1024;
                c.consumer_chunk = 128 * 1024;
                c.record_size = 100;
                c.broker_cores = 16;
                c.mode = smode;
                c.write_mode = wmode;
                c.store_mode = store;
                if store == StoreMode::Durable {
                    c.store_segment_bytes = 1 << 20;
                }
                c.workload = Workload::Count;
                c.name = format!("{}+{}+{}", store.name(), smode.name(), wmode.name());
                rows.push((c.name.clone(), c));
            }
        }
    }
    FigureSpec {
        id: "ablation-store",
        title: "Storage tier (memory vs durable WAL+segments) x sources x writers, \
                count workload",
        expectation: "durable rows pay the WAL append on the write path but keep \
                      read-path totals identical to memory; flushes and compaction \
                      run in the background without stalling consumers",
        rows,
    }
}

/// Ablation — per-stage latency across sources × writers, with the
/// tracing plane sampling every record. The question the paper asserts
/// but never measures (§II-B, §VI): how much sooner does a pushed record
/// reach its operator than a pulled one, and which stage pays for it?
/// The full design-space surface (with the JSON artifact) lives in
/// [`latency::run_and_record`]; this spec is the figure-style cut: all
/// four source modes on the sync writer, pull vs push on the other two.
pub fn ablation_latency(duration: u64) -> FigureSpec {
    let mut rows = Vec::new();
    let mut push_row = |smode: SourceMode, wmode: WriteMode| {
        let mut c = base(duration);
        c.np = 4;
        c.nc = 4;
        c.nmap = 8;
        c.ns = 8;
        c.producer_chunk = 16 * 1024;
        c.consumer_chunk = 128 * 1024;
        c.record_size = 100;
        c.broker_cores = 16;
        c.mode = smode;
        c.write_mode = wmode;
        c.workload = Workload::Count;
        c.trace_sample_permille = 1000;
        c.name = format!("{}+{}", smode.name(), wmode.name());
        rows.push((c.name.clone(), c));
    };
    for &smode in &SourceMode::ALL {
        push_row(smode, WriteMode::SyncRpc);
    }
    for &wmode in &[WriteMode::Pipelined, WriteMode::SharedMem] {
        push_row(SourceMode::Pull, wmode);
        push_row(SourceMode::Push, wmode);
    }
    FigureSpec {
        id: "ablation-latency",
        title: "Per-stage latency (traced): sources x writers, count workload",
        expectation: "push's deliver stage (seal/notify) beats pull's poll round-trip \
                      at p50 and p99; native closes spans at the source (no operate \
                      stage); sharedmem cuts the append stage to the seal notify",
        rows,
    }
}

/// Ablation — multi-broker scale-out: the same count workload on 1, 2 and
/// 3 brokers, pull vs push, plus rebalance rows that force a live
/// partition hand-off (freeze → promote → publish) mid-run. The question
/// §VI's colocation argument raises but the paper never tests: does the
/// pull/push contrast survive sharding the log across brokers, and what
/// does a live ownership change cost each read path? Partitions and
/// consumers are chosen divisible by every broker count (the shard table
/// deals whole consumer spans, so each source keeps a single home
/// broker); rebalance rows run replica sets at `replication_factor = 2`
/// so the incoming primary already holds the bytes it must serve.
pub fn ablation_shard(duration: u64) -> FigureSpec {
    let mut rows = Vec::new();
    let mut push_row = |brokers: usize, smode: SourceMode, rebalance: bool| {
        let mut c = base(duration);
        c.np = 4;
        c.nc = 6;
        c.nmap = 8;
        c.ns = 6;
        c.producer_chunk = 16 * 1024;
        c.consumer_chunk = 128 * 1024;
        c.record_size = 100;
        c.broker_cores = 16;
        c.mode = smode;
        c.workload = Workload::Count;
        c.broker_count = brokers;
        if rebalance {
            c.replication_factor = 2;
            c.rebalance_at_secs = (duration / 2).max(1);
        }
        c.name = format!(
            "bc{}{}+{}",
            brokers,
            if rebalance { "-rebal" } else { "" },
            smode.name()
        );
        rows.push((c.name.clone(), c));
    };
    for &brokers in &[1usize, 2, 3] {
        for &smode in &[SourceMode::Pull, SourceMode::Push] {
            push_row(brokers, smode, false);
        }
    }
    for &smode in &[SourceMode::Pull, SourceMode::Push] {
        push_row(3, smode, true);
    }
    FigureSpec {
        id: "ablation-shard",
        title: "Multi-broker scale-out: broker_count ∈ {1,2,3}, pull vs push, \
                with live-rebalance rows (rf=2)",
        expectation: "totals identical across broker counts (sharding only spreads \
                      the log); per-broker write contention drops with bc; rebalance \
                      rows report a short hand-off and sources re-home without loss",
        rows,
    }
}

/// Ablations beyond the paper's figures (DESIGN.md §4).
pub fn ablations(duration: u64) -> Vec<FigureSpec> {
    let mut specs = Vec::new();

    // (0) the hybrid mode against its parents (quick chunk sweep).
    specs.push(ablation_hybrid(duration, &[4, 32, 128]));

    // (0a) per-stage latency through the tracing plane.
    specs.push(ablation_latency(duration));

    // (0b) the write-path modes against the source modes (quick sweep).
    specs.push(ablation_writepath(duration, &[4, 128]));

    // (0c) checkpoint & recovery across the source/write design space.
    specs.push(ablation_checkpoint(duration));

    // (0d) the storage tier: in-memory vs durable WAL + cold segments.
    specs.push(ablation_store(duration));

    // (0e) multi-broker scale-out with live rebalancing.
    specs.push(ablation_shard(duration));

    // (a) push backpressure window: objects per source.
    let mut rows = Vec::new();
    for objects in [1usize, 2, 4, 8, 16] {
        let mut c = base(duration);
        c.mode = SourceMode::Push;
        c.push_objects_per_source = objects;
        c.name = format!("objects{objects}");
        rows.push((c.name.clone(), c));
    }
    specs.push(FigureSpec {
        id: "ablation-objects",
        title: "Push backpressure window: shared objects per source",
        expectation: "1 object serialises fill/consume; >=2 pipelines them; \
                      diminishing returns after a few",
        rows,
    });

    // (b) network profile: the §VII claim that push matters more on
    // commodity networks.
    let mut rows = Vec::new();
    for (net, label) in [("infiniband", "ib"), ("commodity", "10g")] {
        for mode in [SourceMode::Pull, SourceMode::Push] {
            let mut c = base(duration);
            c.mode = mode;
            c.cost.apply_one("network", net).unwrap();
            c.name = format!("{}-{}", label, mode.name());
            rows.push((c.name.clone(), c));
        }
    }
    specs.push(FigureSpec {
        id: "ablation-network",
        title: "Network profile: Infiniband vs commodity 10G",
        expectation: "push's relative advantage grows on the slower network \
                      (producers own the ingest link; consumers are local)",
        rows,
    });

    // (c) pull poll timeout sensitivity.
    let mut rows = Vec::new();
    for timeout_us in [10u64, 100, 1_000, 10_000] {
        let mut c = base(duration);
        c.mode = SourceMode::Pull;
        c.np = 1;
        c.producer_chunk = 2 * 1024; // slow producers: consumers poll often
        c.pull_timeout_us = timeout_us;
        c.name = format!("timeout{timeout_us}us");
        rows.push((c.name.clone(), c));
    }
    specs.push(FigureSpec {
        id: "ablation-timeout",
        title: "Pull poll-timeout sensitivity (consumer ahead of producers)",
        expectation: "long timeouts add consume latency when caught up; short \
                      timeouts burn RPCs (§II-B: 'difficult to tune')",
        rows,
    });

    // (d) push fan-in: consumers sharing the single push/consume pair.
    let mut rows = Vec::new();
    for nc in [1usize, 2, 4, 8] {
        let mut c = base(duration);
        c.mode = SourceMode::Push;
        c.np = 8;
        c.nc = nc;
        c.ns = 8;
        c.name = format!("push-fanin{nc}");
        rows.push((c.name.clone(), c));
    }
    specs.push(FigureSpec {
        id: "ablation-fanin",
        title: "Push fan-in: sources sharing the dedicated thread pair",
        expectation: "consumer throughput plateaus with Nc (the Fig. 4 \
                      non-scaling, isolated)",
        rows,
    });

    // (e) inter-task queue capacity (credit window).
    let mut rows = Vec::new();
    for cap in [1usize, 2, 8, 32] {
        let mut c = base(duration);
        c.mode = SourceMode::Push;
        c.queue_cap = cap;
        c.name = format!("queue{cap}");
        rows.push((c.name.clone(), c));
    }
    specs.push(FigureSpec {
        id: "ablation-queue",
        title: "Credit window (queue capacity) between tasks",
        expectation: "tiny windows stall sources on queue hops; a few batches \
                      of slack recovers throughput",
        rows,
    });

    specs
}

/// All paper figures at a given per-row duration and chunk sweep.
pub fn all_figures(duration: u64, chunk_sizes: &[usize]) -> Vec<FigureSpec> {
    vec![
        fig3(duration, chunk_sizes),
        fig4(duration, chunk_sizes),
        fig5(duration, chunk_sizes),
        fig6(duration, chunk_sizes),
        fig7(duration, chunk_sizes),
        fig8(duration),
        fig9(duration),
    ]
}

/// Run a figure spec (sim plane) and print the paper-style rows.
pub fn run_figure(spec: &FigureSpec) -> Vec<RunSummary> {
    println!("== {} — {}", spec.id, spec.title);
    println!("   expectation: {}", spec.expectation);
    let mut out = Vec::new();
    for (_label, config) in &spec.rows {
        let summary = launch(config, None).run();
        println!("   {}", summary.report.row());
        if spec.id == "ablation-writepath" {
            println!(
                "      write[{}]: append latency {:>8.1} us  acked {}  errors {}",
                config.write_mode.name(),
                summary.report.gauge("write_append_latency_us").unwrap_or(0.0),
                summary.writers.appends_acked,
                summary.writers.extra(crate::producer::WriteStatKey::Errors),
            );
        }
        if spec.id == "ablation-store" && config.store_mode == StoreMode::Durable {
            let g = |k| summary.report.gauge(k).unwrap_or(0.0);
            println!(
                "      store[durable]: wal {:>9.0} recs {:>7.1} MiB ({:.0} files, \
                 {:.0} pruned)  flushed {:>4.0} segs  compactions {:>3.0}  \
                 cold loads {:>4.0} (cache hits {:>4.0})",
                g("broker.store_wal_records"),
                g("broker.store_wal_bytes") / (1024.0 * 1024.0),
                g("broker.store_wal_files"),
                g("broker.store_wal_pruned"),
                g("broker.store_segments_flushed"),
                g("broker.store_compactions"),
                g("broker.store_cold_loads"),
                g("broker.store_cold_cache_hits"),
            );
        }
        if spec.id == "ablation-latency" {
            let lat = &summary.latency;
            for s in &lat.stages {
                println!(
                    "      lat[{:<10}] n {:>8}  p50 {:>9.1} us  p99 {:>9.1} us  \
                     p999 {:>9.1} us",
                    s.stage.name(),
                    s.count,
                    s.p50_ns as f64 / 1e3,
                    s.p99_ns as f64 / 1e3,
                    s.p999_ns as f64 / 1e3,
                );
            }
            println!(
                "      spans: {} completed, {} dropped",
                lat.spans_completed, lat.spans_dropped
            );
        }
        if spec.id == "ablation-shard" && config.broker_count > 1 {
            let g = |k| summary.report.gauge(k).unwrap_or(0.0);
            println!(
                "      shard: brokers {:>2.0}  rebalances {:>2.0}  \
                 partitions moved {:>2.0}  handoff {:>7.3} ms",
                g("shard.brokers"),
                g("shard.rebalances"),
                g("shard.partitions_moved"),
                g("shard.handoff_ms"),
            );
        }
        if spec.id == "ablation-checkpoint" && config.checkpoint_interval_ms > 0 {
            let ck = &summary.checkpoints;
            println!(
                "      ckpt: epochs {:>3} (skipped {})  mean epoch {:>7.3} ms  \
                 max align {:>7.3} ms  recoveries {}  recovery {:>7.3} ms  replayed {}",
                ck.epochs_completed,
                ck.epochs_skipped,
                ck.mean_epoch_ns() as f64 / 1e6,
                ck.align_ns_max as f64 / 1e6,
                ck.recoveries,
                ck.last_recovery_ns as f64 / 1e6,
                ck.records_replayed,
            );
        }
        out.push(summary);
    }
    out
}

/// Table II — the benchmark/operator matrix, printable.
pub fn table2() -> String {
    let rows = [
        ("Count, broker 16 cores (Fig.4)", "-", "x", "x", "-"),
        ("Filter, 8 partitions (Fig.5)", "x", "x", "x", "-"),
        ("Filter, 4 partitions (Fig.6)", "x", "x", "x", "-"),
        ("Filter, broker 4 cores (Fig.7)", "x", "x", "x", "-"),
        ("Small chunks, broker 8 cores (Fig.8)", "-", "x", "x", "-"),
        ("Windowed Word Count (Fig.9)", "-", "x", "x", "x"),
    ];
    let mut s = String::from(
        "Benchmarks Pull versus Push             | Filter | Count | Map | KeyBy\n",
    );
    for (name, f, c, m, k) in rows {
        s.push_str(&format!("{name:<40}|   {f}    |   {c}   |  {m}  |   {k}\n"));
    }
    s
}
