//! The latency ablation: per-stage end-to-end latency across the whole
//! source × write design space, measured by the tracing plane.
//!
//! The paper's evaluation (§V) compares pull and push on *throughput*;
//! its central latency claim — push "avoids the consume path" so a
//! record reaches the operator sooner, while pull pays the poll
//! round-trip — is asserted, never measured. This harness measures it:
//! every cell runs the same count workload with the tracer fully on
//! (`trace_sample_permille = 1000`), so every record's produce → append
//! → deliver → consume → operate life lands in the per-stage histograms
//! ([`crate::obs`]), and the cell reports p50/p95/p99/p999 per stage
//! plus the end-to-end span.
//!
//! Results go to `BENCH_latency.json` (hand-rolled JSON, same idiom as
//! [`super::hotpath`]) so CI can upload the latency surface as an
//! artifact and regressions in either tail are diffable run-over-run.

use crate::cluster::launch;
use crate::config::{ExecPlane, ExperimentConfig, SourceMode, Workload, WriteMode};
use crate::obs::{LatencyReport, Stage};

/// One (source mode × write mode) cell: the latency report plus the
/// totals that make a latency diff interpretable ("slower" vs "doing
/// different work").
#[derive(Debug, Clone)]
pub struct LatencyCell {
    /// `"sim"` (virtual-clock spans, cost-model deltas) or `"real"`
    /// (wall-clock spans on OS threads + TCP — see `Tracer::set_wall_clock`).
    pub plane: &'static str,
    pub source: &'static str,
    pub write: &'static str,
    /// Virtual horizon for sim cells; 0 for real cells (bounded corpus).
    pub virtual_secs: u64,
    pub records_consumed: u64,
    pub latency: LatencyReport,
}

/// The whole sweep.
#[derive(Debug, Clone)]
pub struct LatencyBenchReport {
    pub cells: Vec<LatencyCell>,
}

/// The per-cell config: the Fig. 4-style count workload on a fixed seed
/// with the tracer sampling every record. Identical modelled work across
/// cells, so latency differences are design differences.
fn cell_config(source: SourceMode, write: WriteMode, secs: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("latency-{}-{}", source.name(), write.name()),
        np: 4,
        nc: 4,
        nmap: 8,
        ns: 8,
        producer_chunk: 16 * 1024,
        consumer_chunk: 128 * 1024,
        record_size: 100,
        broker_cores: 16,
        mode: source,
        write_mode: write,
        workload: Workload::Count,
        duration_secs: secs,
        warmup_secs: 1,
        trace_sample_permille: 1000,
        ..Default::default()
    }
}

fn run_cell(source: SourceMode, write: WriteMode, secs: u64) -> LatencyCell {
    let config = cell_config(source, write, secs);
    let summary = launch(&config, None).run();
    LatencyCell {
        plane: "sim",
        source: source.name(),
        write: write.name(),
        virtual_secs: secs,
        records_consumed: summary.records_consumed,
        latency: summary.latency,
    }
}

/// One real-plane cell: the same config shape on OS threads + TCP with a
/// bounded corpus. Spans are wall-clock against a process-wide epoch, so
/// these numbers are what the actual execution plane delivers (scheduler
/// noise and all) — comparable run to run on the same host, not to the
/// sim cells' cost-model deltas.
fn run_real_cell(source: SourceMode, write: WriteMode, corpus_records: u64) -> LatencyCell {
    let mut config = cell_config(source, write, 2);
    config.name = format!("latency-real-{}-{}", source.name(), write.name());
    config.plane = ExecPlane::Real;
    config.corpus_records = corpus_records;
    let summary = crate::real::run_cluster(&config)
        .unwrap_or_else(|e| panic!("real-plane latency cell {}: {e}", config.name));
    LatencyCell {
        plane: "real",
        source: source.name(),
        write: write.name(),
        virtual_secs: 0,
        records_consumed: summary.records_consumed,
        latency: summary.latency,
    }
}

fn fmt_us(ns: u64) -> f64 {
    ns as f64 / 1e3
}

fn print_cell(cell: &LatencyCell) {
    let e2e = cell.latency.stage(Stage::EndToEnd);
    let (p50, p99) = e2e.map(|s| (s.p50_ns, s.p99_ns)).unwrap_or((0, 0));
    println!(
        "   {:<4} {:<8}x {:<10} e2e p50 {:>9.1} us  p99 {:>9.1} us  spans {:>8}  \
         dropped {:>5}  cons {:>9}",
        cell.plane,
        cell.source,
        cell.write,
        fmt_us(p50),
        fmt_us(p99),
        cell.latency.spans_completed,
        cell.latency.spans_dropped,
        cell.records_consumed,
    );
    for s in &cell.latency.stages {
        if s.stage == Stage::EndToEnd {
            continue;
        }
        println!(
            "      {:<8} n {:>8}  p50 {:>9.1} us  p95 {:>9.1} us  p99 {:>9.1} us  \
             p999 {:>9.1} us",
            s.stage.name(),
            s.count,
            fmt_us(s.p50_ns),
            fmt_us(s.p95_ns),
            fmt_us(s.p99_ns),
            fmt_us(s.p999_ns),
        );
    }
}

/// Run the full 4 sources × 3 writers sim sweep, then the two real-plane
/// anchor cells (the paper's baseline and its thesis design, wall-clock),
/// and print the surface.
pub fn run_latency(quick: bool) -> LatencyBenchReport {
    let secs = if quick { 4 } else { 12 };
    println!("== latency — per-stage end-to-end latency, sources x writers (traced)");
    let mut cells = Vec::new();
    for &source in &SourceMode::ALL {
        for &write in &WriteMode::ALL {
            let cell = run_cell(source, write, secs);
            print_cell(&cell);
            cells.push(cell);
        }
    }
    let corpus = if quick { 20_000 } else { 100_000 };
    let real_cells =
        [(SourceMode::Pull, WriteMode::SyncRpc), (SourceMode::Push, WriteMode::SharedMem)];
    for (source, write) in real_cells {
        let cell = run_real_cell(source, write, corpus);
        print_cell(&cell);
        cells.push(cell);
    }
    LatencyBenchReport { cells }
}

/// Write `BENCH_latency.json`. Hand-rolled JSON — the offline vendor set
/// has no serde; one object per cell, one object per stage.
pub fn write_json(path: &std::path::Path, report: &LatencyBenchReport) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"zettastream-bench-latency/v2\",\n");
    s.push_str("  \"cells\": [\n");
    for (i, c) in report.cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"plane\": \"{}\", \"source\": \"{}\", \"write\": \"{}\", \
             \"virtual_secs\": {}, \
             \"records_consumed\": {}, \"spans_completed\": {}, \"spans_dropped\": {}, \
             \"stages\": [",
            c.plane,
            c.source,
            c.write,
            c.virtual_secs,
            c.records_consumed,
            c.latency.spans_completed,
            c.latency.spans_dropped,
        ));
        for (j, st) in c.latency.stages.iter().enumerate() {
            s.push_str(&format!(
                "{{\"stage\": \"{}\", \"count\": {}, \"p50_ns\": {}, \"p95_ns\": {}, \
                 \"p99_ns\": {}, \"p999_ns\": {}}}{}",
                st.stage.name(),
                st.count,
                st.p50_ns,
                st.p95_ns,
                st.p99_ns,
                st.p999_ns,
                if j + 1 == c.latency.stages.len() { "" } else { ", " },
            ));
        }
        s.push_str(&format!(
            "]}}{}\n",
            if i + 1 == report.cells.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)
}

/// The CLI/bench entry point: run the sweep and record the artifact.
pub fn run_and_record(quick: bool, path: &std::path::Path) -> LatencyBenchReport {
    let report = run_latency(quick);
    match write_json(path, &report) {
        Ok(()) => println!("   wrote {}", path.display()),
        Err(e) => eprintln!("   could not write {}: {e}", path.display()),
    }
    report
}
