//! The chaos harness: scripted broker kills across the whole
//! source × write design space, with golden-totals parity as the pass
//! criterion.
//!
//! The fail-over subsystem ([`crate::shard`]) promises that a broker
//! death at `replication_factor >= 2` is invisible in the totals: the
//! coordinator's heartbeat detector declares the corpse, promotes each
//! orphaned partition's standing replica in an emergency epoch, and every
//! writer and source re-routes — bounded retries on the write path,
//! reissued pulls / re-homed subscriptions on the read path. This harness
//! *measures* that promise instead of trusting it: every cell runs a
//! bounded count workload twice on the same seed — once fault-free, once
//! with a scripted mid-run broker kill — and the two runs must agree on
//! every total (produced, consumed, logged) and on the closed form
//! `Np × corpus_records`. Zero loss, zero duplication, or the harness
//! panics.
//!
//! Two kill schedules bracket the interesting timing space:
//!
//! * `mid-write` — throttled producers stretch the corpus over ~2 virtual
//!   seconds; the kill at t=1 s lands while appends (and their quorum
//!   replication) are in flight, exercising the write-path deadline retry
//!   and the append dedup table on the promoted primary.
//! * `mid-drain` — fast producers, slow consumers: the corpus is fully
//!   durable before the kill, but the readers still need history from the
//!   dead primary, exercising the read-path re-route (reissued pulls,
//!   push re-homes at the consumed floor, hybrid forced-pull fallback).
//!
//! Results go to `BENCH_chaos.json` (hand-rolled JSON, same idiom as
//! [`super::latency`]) so CI can diff detection time and retry counts
//! run-over-run.

use crate::cluster::launch;
use crate::config::{ExperimentConfig, FaultKind, SourceMode, Workload, WriteMode};

const NP: u64 = 2;
const CORPUS: u64 = 2_000;
const SEED: u64 = 0xC0FFEE;

/// One scripted kill: when the broker dies and how the record costs shape
/// the run around it (who is still busy when the kill lands).
#[derive(Debug, Clone, Copy)]
pub struct KillSchedule {
    pub label: &'static str,
    /// Virtual second the victim broker drops dead.
    pub fault_at_secs: u64,
    /// Producer throttle (ns/record); 1 ms stretches the corpus past the
    /// kill so appends cross the fail-over.
    pub producer_record_ns: u64,
    /// Consumer throttle (ns/record); 1 ms leaves the readers holding a
    /// backlog on the corpse.
    pub engine_record_ns: u64,
}

/// The scripted schedules, slowest-path first.
pub const SCHEDULES: [KillSchedule; 2] = [
    KillSchedule {
        label: "mid-write",
        fault_at_secs: 1,
        producer_record_ns: 1_000_000,
        engine_record_ns: 0,
    },
    KillSchedule {
        label: "mid-drain",
        fault_at_secs: 1,
        producer_record_ns: 0,
        engine_record_ns: 1_000_000,
    },
];

/// One (schedule × source × write) cell's outcome.
#[derive(Debug, Clone)]
pub struct ChaosCell {
    pub schedule: &'static str,
    pub source: &'static str,
    pub write: &'static str,
    pub produced: u64,
    pub consumed: u64,
    pub logged: u64,
    /// The closed form: `Np × corpus_records`.
    pub expect: u64,
    pub failovers: f64,
    pub promotions: f64,
    pub detection_ms: f64,
    pub write_retries: f64,
    pub source_retries: f64,
    /// Faulted totals == fault-free totals == closed form.
    pub parity: bool,
}

/// The whole sweep.
#[derive(Debug, Clone)]
pub struct ChaosBenchReport {
    pub cells: Vec<ChaosCell>,
}

impl ChaosBenchReport {
    /// Every cell held parity (the harness's pass criterion).
    pub fn all_pass(&self) -> bool {
        self.cells.iter().all(|c| c.parity)
    }
}

/// The faulted cell: bc=3, rf=2, one broker killed mid-run. The shape
/// mirrors `tests/shard_rebalance.rs` so the rebalance and fail-over
/// suites cover the same topology.
pub fn chaos_config(
    source: SourceMode,
    write: WriteMode,
    schedule: &KillSchedule,
) -> ExperimentConfig {
    let mut c = ExperimentConfig {
        name: format!("chaos-{}-{}-{}", schedule.label, source.name(), write.name()),
        np: NP as usize,
        nc: 3,
        nmap: 4,
        ns: 6,
        producer_chunk: 4 * 1024,
        consumer_chunk: 16 * 1024,
        record_size: 100,
        broker_cores: 8,
        mode: source,
        write_mode: write,
        workload: Workload::Count,
        corpus_records: CORPUS,
        duration_secs: 12,
        warmup_secs: 1,
        seed: SEED,
        broker_count: 3,
        replication_factor: 2,
        fault_at_secs: schedule.fault_at_secs,
        fault_kind: FaultKind::Broker,
        ..Default::default()
    };
    c.cost.producer_record_ns = schedule.producer_record_ns;
    c.cost.engine_record_ns = schedule.engine_record_ns;
    c
}

/// The same cell with the kill disarmed: same seed, same topology, same
/// generators — the golden run the faulted totals must match.
fn baseline_config(
    source: SourceMode,
    write: WriteMode,
    schedule: &KillSchedule,
) -> ExperimentConfig {
    let mut c = chaos_config(source, write, schedule);
    c.name = format!("chaos-base-{}-{}-{}", schedule.label, source.name(), write.name());
    c.fault_at_secs = 0;
    c
}

fn run_cell(source: SourceMode, write: WriteMode, schedule: &KillSchedule) -> ChaosCell {
    let faulted = launch(&chaos_config(source, write, schedule), None).run();
    let golden = launch(&baseline_config(source, write, schedule), None).run();
    let expect = NP * CORPUS;
    let g = |k| faulted.report.gauge(k).unwrap_or(0.0);
    let parity = faulted.records_produced == expect
        && faulted.records_consumed == expect
        && faulted.tuples_logged == expect
        && golden.records_produced == faulted.records_produced
        && golden.records_consumed == faulted.records_consumed
        && golden.tuples_logged == faulted.tuples_logged;
    ChaosCell {
        schedule: schedule.label,
        source: source.name(),
        write: write.name(),
        produced: faulted.records_produced,
        consumed: faulted.records_consumed,
        logged: faulted.tuples_logged,
        expect,
        failovers: g("shard.failovers"),
        promotions: g("shard.promotions"),
        detection_ms: g("shard.detection_ms"),
        write_retries: g("write_broker_down_retries"),
        source_retries: g("source_broker_down_retries"),
        parity,
    }
}

fn print_cell(cell: &ChaosCell) {
    println!(
        "   {:<9} {:<8}x {:<10} {}  produced {:>6}  consumed {:>6}  logged {:>6} \
         (expect {})  failovers {:>2.0}  promoted {:>2.0}  detect {:>7.1} ms  \
         retries w{:>3.0}/r{:>3.0}",
        cell.schedule,
        cell.source,
        cell.write,
        if cell.parity { "OK  " } else { "FAIL" },
        cell.produced,
        cell.consumed,
        cell.logged,
        cell.expect,
        cell.failovers,
        cell.promotions,
        cell.detection_ms,
        cell.write_retries,
        cell.source_retries,
    );
}

/// Run the sweep: every source × write cell under each scripted kill
/// (quick mode runs only the `mid-write` schedule). Panics if any cell
/// loses parity — the harness is an assertion, not a survey.
pub fn run_chaos(quick: bool) -> ChaosBenchReport {
    println!(
        "== chaos — broker kill mid-run, sources x writers, golden-totals parity \
         (bc=3, rf=2)"
    );
    let schedules: &[KillSchedule] = if quick { &SCHEDULES[..1] } else { &SCHEDULES };
    let mut cells = Vec::new();
    for schedule in schedules {
        for &source in &SourceMode::ALL {
            for &write in &WriteMode::ALL {
                let cell = run_cell(source, write, schedule);
                print_cell(&cell);
                cells.push(cell);
            }
        }
    }
    let report = ChaosBenchReport { cells };
    assert!(
        report.all_pass(),
        "chaos parity violated: a broker death changed the totals (see FAIL rows)"
    );
    report
}

/// Write `BENCH_chaos.json`. Hand-rolled JSON — the offline vendor set
/// has no serde.
pub fn write_json(path: &std::path::Path, report: &ChaosBenchReport) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"zettastream-bench-chaos/v1\",\n");
    s.push_str("  \"cells\": [\n");
    for (i, c) in report.cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"schedule\": \"{}\", \"source\": \"{}\", \"write\": \"{}\", \
             \"produced\": {}, \"consumed\": {}, \"logged\": {}, \"expect\": {}, \
             \"failovers\": {}, \"promotions\": {}, \"detection_ms\": {:.3}, \
             \"write_broker_down_retries\": {}, \"source_broker_down_retries\": {}, \
             \"parity\": {}}}{}\n",
            c.schedule,
            c.source,
            c.write,
            c.produced,
            c.consumed,
            c.logged,
            c.expect,
            c.failovers,
            c.promotions,
            c.detection_ms,
            c.write_retries,
            c.source_retries,
            c.parity,
            if i + 1 == report.cells.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)
}

/// The CLI/bench entry point: run the sweep and record the artifact.
pub fn run_and_record(quick: bool, path: &std::path::Path) -> ChaosBenchReport {
    let report = run_chaos(quick);
    match write_json(path, &report) {
        Ok(()) => println!("   wrote {}", path.display()),
        Err(e) => eprintln!("   could not write {}: {e}", path.display()),
    }
    report
}
