//! The hot-path benchmark harness: how fast does the *simulator itself*
//! run — the enabling metric for every figure sweep in this repo.
//!
//! Measures (wall-clock, so run on an idle machine):
//!
//! * raw DES engine event throughput (a two-actor ping-pong micro);
//! * the **cluster-sim target**: virtual-vs-wall ratio and DES events/sec
//!   of a canonical pull+sync count cluster — the number the perf
//!   acceptance gate tracks;
//! * the full design-space sweep: all four source modes × all three write
//!   modes on the same workload and seed (plus one `store_mode=durable`
//!   cell on the acceptance-gate configuration, so disk-path regressions
//!   show up in the artifact), each cell reporting events/sec,
//!   virtual/wall speed and the run's cross-checkable totals;
//! * two **real-plane** cells (`plane=real`: OS threads + TCP, bounded
//!   corpus to quiescence) — the paper's pull+sync baseline and its
//!   push+sharedmem thesis design — reporting wall-clock events/sec so the
//!   artifact tracks what the actual execution plane sustains, not just
//!   the simulator.
//!
//! Results are written to `BENCH_hotpath.json` (machine-readable; CI
//! uploads it as an artifact) so the perf trajectory has a recorded
//! baseline: on every run, the previous file's `cluster_events_per_s` is
//! scanned out first and reported as the baseline speedup. Totals are in
//! the file too, so a perf regression hunt can immediately tell "slower"
//! apart from "doing different work".

use std::time::Instant;

use crate::cluster::launch;
use crate::config::{ExecPlane, ExperimentConfig, SourceMode, StoreMode, Workload, WriteMode};
use crate::sim::{Actor, ActorId, Ctx, Engine, SECOND};

/// One (source mode × write mode) cell of the sweep.
#[derive(Debug, Clone)]
pub struct HotpathCell {
    /// Which execution plane ran this cell: `"sim"` (DES engine, virtual
    /// clock) or `"real"` (OS threads + TCP, wall clock, bounded corpus).
    pub plane: &'static str,
    pub source: &'static str,
    pub write: &'static str,
    pub store: &'static str,
    /// Broker count the cell ran with (1 everywhere except the sharded
    /// scale-out cell).
    pub brokers: usize,
    /// Virtual horizon for sim cells; 0 for real cells (they run a bounded
    /// corpus to quiescence instead of a virtual horizon).
    pub virtual_secs: u64,
    pub events: u64,
    pub wall_secs: f64,
    pub events_per_s: f64,
    /// Virtual seconds simulated per wall second.
    pub virt_per_wall: f64,
    pub records_produced: u64,
    pub records_consumed: u64,
    pub tuples_logged: u64,
}

/// The whole harness result.
#[derive(Debug, Clone)]
pub struct HotpathReport {
    /// Raw engine micro-benchmark (ping-pong), events/sec.
    pub engine_events_per_s: f64,
    /// The acceptance-gate number: DES events/sec of the canonical
    /// cluster-sim target (pull source, sync writer, count workload).
    pub cluster_events_per_s: f64,
    /// Same target, virtual seconds per wall second.
    pub cluster_virt_per_wall: f64,
    /// Previous `cluster_events_per_s` scanned from the existing JSON
    /// (the pre-run baseline), if any.
    pub baseline_cluster_events_per_s: Option<f64>,
    pub cells: Vec<HotpathCell>,
}

impl HotpathReport {
    /// Speedup of the cluster-sim target vs the recorded baseline.
    pub fn speedup_vs_baseline(&self) -> Option<f64> {
        self.baseline_cluster_events_per_s
            .filter(|&b| b > 0.0)
            .map(|b| self.cluster_events_per_s / b)
    }
}

struct PingPong {
    peer: Option<ActorId>,
    left: u64,
}

impl Actor<u32> for PingPong {
    fn on_start(&mut self, ctx: &mut Ctx<'_, u32>) {
        if self.peer.is_some() {
            ctx.send_self_in(1, 0);
        }
    }
    fn on_event(&mut self, _m: u32, ctx: &mut Ctx<'_, u32>) {
        if self.left == 0 {
            return;
        }
        self.left -= 1;
        match self.peer {
            Some(peer) => ctx.send_in(1, peer, 0),
            None => ctx.send_self_in(1, 0),
        }
    }
}

/// Raw engine throughput: a two-actor ping-pong, events/sec.
pub fn bench_engine_events_per_s(events: u64) -> f64 {
    let mut engine: Engine<u32> = Engine::new(1);
    let a = engine.add_actor(Box::new(PingPong { peer: None, left: events }));
    let _b = engine.add_actor(Box::new(PingPong { peer: Some(a), left: events }));
    let t0 = Instant::now();
    engine.run_to_quiescence();
    engine.events_processed() as f64 / t0.elapsed().as_secs_f64()
}

/// The sweep's per-cell config: the Fig. 4-style count workload on a fixed
/// seed — identical modelled work across every cell, so events/sec
/// differences are simulator cost, not workload drift.
fn cell_config(
    source: SourceMode,
    write: WriteMode,
    store: StoreMode,
    secs: u64,
) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("hotpath-{}-{}-{}", source.name(), write.name(), store.name()),
        np: 4,
        nc: 4,
        nmap: 8,
        ns: 8,
        broker_cores: 16,
        mode: source,
        write_mode: write,
        store_mode: store,
        workload: Workload::Count,
        duration_secs: secs,
        warmup_secs: 1,
        ..Default::default()
    }
}

fn run_cell(source: SourceMode, write: WriteMode, store: StoreMode, secs: u64) -> HotpathCell {
    run_cell_with(cell_config(source, write, store, secs), secs)
}

/// The sharded scale-out cell: the acceptance-gate shape dealt across
/// three brokers (partitions/consumers bumped to 6 so the table divides
/// evenly — see `crate::shard`).
fn run_sharded_cell(secs: u64) -> HotpathCell {
    let mut config = cell_config(SourceMode::Pull, WriteMode::SyncRpc, StoreMode::Memory, secs);
    config.name = "hotpath-pull-sync-bc3".to_string();
    config.ns = 6;
    config.nc = 6;
    config.broker_count = 3;
    run_cell_with(config, secs)
}

fn run_cell_with(config: ExperimentConfig, secs: u64) -> HotpathCell {
    let mut cluster = launch(&config, None);
    let t0 = Instant::now();
    cluster.engine.run_until(secs * SECOND);
    let wall = t0.elapsed().as_secs_f64();
    let events = cluster.engine.events_processed();
    let summary = cluster.finish();
    HotpathCell {
        plane: "sim",
        source: config.mode.name(),
        write: config.write_mode.name(),
        store: config.store_mode.name(),
        brokers: config.broker_count,
        virtual_secs: secs,
        events,
        wall_secs: wall,
        events_per_s: events as f64 / wall,
        virt_per_wall: secs as f64 / wall,
        records_produced: summary.records_produced,
        records_consumed: summary.records_consumed,
        tuples_logged: summary.tuples_logged,
    }
}

/// One real-plane cell: the same config shape, but `plane=real` with a
/// bounded corpus — OS threads, TCP appends (unless sharedmem), wall-clock
/// throughput. There is no virtual clock, so `virt_per_wall` is null in
/// the artifact and `events_per_s` means *real* engine events per wall
/// second across all node threads.
fn run_real_cell(source: SourceMode, write: WriteMode, corpus_records: u64) -> HotpathCell {
    // The virtual horizon is unused on the real plane (bounded corpus
    // decides termination), but validation still wants duration > warmup.
    let mut config = cell_config(source, write, StoreMode::Memory, 2);
    config.name = format!("hotpath-real-{}-{}", source.name(), write.name());
    config.plane = ExecPlane::Real;
    config.corpus_records = corpus_records;
    let summary = crate::real::run_cluster(&config)
        .unwrap_or_else(|e| panic!("real-plane hotpath cell {}: {e}", config.name));
    HotpathCell {
        plane: "real",
        source: source.name(),
        write: write.name(),
        store: StoreMode::Memory.name(),
        brokers: 1,
        virtual_secs: 0,
        events: summary.events_processed,
        wall_secs: summary.wall_secs,
        events_per_s: summary.events_processed as f64 / summary.wall_secs.max(1e-9),
        virt_per_wall: f64::NAN,
        records_produced: summary.records_produced,
        records_consumed: summary.records_consumed,
        tuples_logged: summary.tuples_logged,
    }
}

/// Run the whole harness: engine micro, cluster-sim target, 4×3 sweep.
/// Prints the rows; returns the report (see [`write_json`]).
pub fn run_hotpath(quick: bool, baseline: Option<f64>) -> HotpathReport {
    let secs = if quick { 4 } else { 12 };
    let micro_events = if quick { 500_000 } else { 2_000_000 };
    println!("== hotpath — simulator hot-path throughput (wall-clock)");
    let engine_eps = bench_engine_events_per_s(micro_events);
    println!(
        "   engine[ping-pong]: {:.2} M events/s ({:.0} ns/event)",
        engine_eps / 1e6,
        1e9 / engine_eps
    );
    let mut cells = Vec::new();
    let mut cluster_eps = 0.0;
    let mut cluster_ratio = 0.0;
    let print_cell = |cell: &HotpathCell| {
        let ratio = if cell.virt_per_wall.is_finite() {
            format!("{:>6.1}x virtual/wall", cell.virt_per_wall)
        } else {
            "  wall-clock      ".to_string()
        };
        println!(
            "   {:<4} {:<8}x {:<10}x {:<8} bc{} {:>7.2} M events/s  {ratio}  \
             events {:>10}  prod {:>9}  cons {:>9}",
            cell.plane,
            cell.source,
            cell.write,
            cell.store,
            cell.brokers,
            cell.events_per_s / 1e6,
            cell.events,
            cell.records_produced,
            cell.records_consumed,
        );
    };
    for &source in &SourceMode::ALL {
        for &write in &WriteMode::ALL {
            let cell = run_cell(source, write, StoreMode::Memory, secs);
            print_cell(&cell);
            // The acceptance-gate target: the paper's baseline ingestion
            // design on the pull path.
            if source == SourceMode::Pull && write == WriteMode::SyncRpc {
                cluster_eps = cell.events_per_s;
                cluster_ratio = cell.virt_per_wall;
            }
            cells.push(cell);
        }
    }
    // One durable-store cell on the acceptance-gate configuration, so the
    // bench artifact tracks the disk path's simulator cost too.
    let cell = run_cell(SourceMode::Pull, WriteMode::SyncRpc, StoreMode::Durable, secs);
    print_cell(&cell);
    cells.push(cell);
    // One sharded cell (broker_count=3) so the scale-out plane's simulator
    // cost — three broker actors, replica fan-out, shard routing — is on
    // the trajectory too.
    let cell = run_sharded_cell(secs);
    print_cell(&cell);
    cells.push(cell);
    // Real-plane cells: the paper's baseline (pull + sync RPC, everything
    // over the wire) and its thesis design (push + shared memory, nothing
    // over the wire) on actual OS threads + TCP — wall-clock events/sec,
    // comparable run to run on the same host.
    let corpus = if quick { 20_000 } else { 100_000 };
    let real_cells =
        [(SourceMode::Pull, WriteMode::SyncRpc), (SourceMode::Push, WriteMode::SharedMem)];
    for (source, write) in real_cells {
        let cell = run_real_cell(source, write, corpus);
        print_cell(&cell);
        cells.push(cell);
    }
    let report = HotpathReport {
        engine_events_per_s: engine_eps,
        cluster_events_per_s: cluster_eps,
        cluster_virt_per_wall: cluster_ratio,
        baseline_cluster_events_per_s: baseline,
        cells,
    };
    match report.speedup_vs_baseline() {
        Some(s) => println!(
            "   cluster-sim target: {:.2} M events/s — {s:.2}x vs recorded baseline",
            cluster_eps / 1e6
        ),
        None => println!(
            "   cluster-sim target: {:.2} M events/s (no recorded baseline yet)",
            cluster_eps / 1e6
        ),
    }
    report
}

/// Scan a previous `BENCH_hotpath.json` for its `cluster_events_per_s`
/// (tolerant string scan — the vendor set has no JSON parser; the field
/// is written by [`write_json`] on one line).
pub fn read_baseline(path: &std::path::Path) -> Option<f64> {
    let body = std::fs::read_to_string(path).ok()?;
    let key = "\"cluster_events_per_s\":";
    let at = body.find(key)? + key.len();
    let rest = body[at..].trim_start();
    // The seed file (and any run that never measured the target) records
    // the field as `null`: that is "no recorded baseline", not a number
    // to compute a speedup against.
    if rest.starts_with("null") {
        return None;
    }
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == '+'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok().filter(|v: &f64| v.is_finite() && *v > 0.0)
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

/// Write the machine-readable trajectory file. Hand-rolled JSON — the
/// offline vendor set has no serde; the schema is flat on purpose.
pub fn write_json(path: &std::path::Path, report: &HotpathReport) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"zettastream-bench-hotpath/v3\",\n");
    s.push_str(&format!(
        "  \"engine_events_per_s\": {},\n",
        json_f64(report.engine_events_per_s)
    ));
    s.push_str(&format!(
        "  \"cluster_events_per_s\": {},\n",
        json_f64(report.cluster_events_per_s)
    ));
    s.push_str(&format!(
        "  \"cluster_virt_per_wall\": {},\n",
        json_f64(report.cluster_virt_per_wall)
    ));
    s.push_str(&format!(
        "  \"baseline_cluster_events_per_s\": {},\n",
        report
            .baseline_cluster_events_per_s
            .map(json_f64)
            .unwrap_or_else(|| "null".to_string())
    ));
    s.push_str(&format!(
        "  \"speedup_vs_baseline\": {},\n",
        report
            .speedup_vs_baseline()
            .map(json_f64)
            .unwrap_or_else(|| "null".to_string())
    ));
    s.push_str("  \"cells\": [\n");
    for (i, c) in report.cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"plane\": \"{}\", \"source\": \"{}\", \"write\": \"{}\", \
             \"store\": \"{}\", \"brokers\": {}, \"virtual_secs\": {}, \
             \"events\": {}, \"wall_secs\": {}, \"events_per_s\": {}, \
             \"virt_per_wall\": {}, \"records_produced\": {}, \
             \"records_consumed\": {}, \"tuples_logged\": {}}}{}\n",
            c.plane,
            c.source,
            c.write,
            c.store,
            c.brokers,
            c.virtual_secs,
            c.events,
            json_f64(c.wall_secs),
            json_f64(c.events_per_s),
            json_f64(c.virt_per_wall),
            c.records_produced,
            c.records_consumed,
            c.tuples_logged,
            if i + 1 == report.cells.len() { "" } else { "," },
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s)
}

/// The CLI/bench entry point: read the old baseline, run, rewrite the
/// file, print where it went.
pub fn run_and_record(quick: bool, path: &std::path::Path) -> HotpathReport {
    let baseline = read_baseline(path);
    let report = run_hotpath(quick, baseline);
    match write_json(path, &report) {
        Ok(()) => println!("   wrote {}", path.display()),
        Err(e) => eprintln!("   could not write {}: {e}", path.display()),
    }
    report
}
