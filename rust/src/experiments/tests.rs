//! Catalog sanity: every figure spec is valid and matches the paper's
//! parameterisation.

use super::*;
use crate::config::DataPlane;

#[test]
fn all_figure_rows_validate() {
    for spec in all_figures(10, &[4, 64]) {
        assert!(!spec.rows.is_empty(), "{} empty", spec.id);
        for (label, config) in &spec.rows {
            config
                .validate()
                .unwrap_or_else(|e| panic!("{}/{label}: {e}", spec.id));
            assert_eq!(config.data_plane, DataPlane::Sim);
        }
    }
}

#[test]
fn ablation_rows_validate() {
    for spec in ablations(10) {
        for (label, config) in &spec.rows {
            config
                .validate()
                .unwrap_or_else(|e| panic!("{}/{label}: {e}", spec.id));
        }
    }
}

#[test]
fn fig3_sweeps_np_replication_chunks() {
    let spec = fig3(10, &CHUNK_SIZES_KIB);
    assert_eq!(spec.rows.len(), 3 * 2 * 8);
    // the paper's naming convention is preserved
    assert!(spec.rows.iter().any(|(l, _)| l == "R1Prods2/cs128KiB"));
    assert!(spec.rows.iter().any(|(l, _)| l == "R2Prods8/cs1KiB"));
}

#[test]
fn fig4_uses_16_core_broker_and_fixed_consumer_chunk() {
    for (_, c) in &fig4(10, &[4]).rows {
        assert_eq!(c.broker_cores, 16);
        assert_eq!(c.consumer_chunk, 128 * 1024);
        assert_eq!(c.ns, 8);
        assert_eq!(c.record_size, 100);
    }
}

#[test]
fn fig7_is_the_constrained_configuration() {
    let spec = fig7(10, &[4, 32]);
    for (_, c) in &spec.rows {
        assert_eq!(c.broker_cores, 4);
        assert_eq!(c.replication, 2);
        assert_eq!(c.np, 4);
        assert_eq!(c.nc, 4);
        assert_eq!(c.consumer_chunk, c.producer_chunk, "Fig.7: consumer CS = producer CS");
    }
    // all three strategies present
    let modes: std::collections::HashSet<&str> =
        spec.rows.iter().map(|(_, c)| c.mode.name()).collect();
    assert_eq!(modes.len(), 3);
}

#[test]
fn fig8_consumer_chunk_is_8x() {
    for (_, c) in &fig8(10).rows {
        assert_eq!(c.consumer_chunk, 8 * c.producer_chunk, "Fig.8: 8x higher chunks");
        assert_eq!(c.broker_cores, 8);
        assert!(c.producer_chunk <= 4 * 1024);
    }
}

#[test]
fn fig9_is_text_workloads_on_4_partitions() {
    let spec = fig9(10);
    assert_eq!(spec.rows.len(), 2 * 2 * 3);
    for (_, c) in &spec.rows {
        assert_eq!(c.ns, 4);
        assert_eq!(c.record_size, 2048);
        assert!(c.workload.is_text());
        assert_eq!(c.nmap, 8);
    }
    assert!(spec.rows.iter().any(|(l, _)| l == "FLCons2"), "paper's label scheme");
    assert!(spec.rows.iter().any(|(l, _)| l == "FPLCons4"));
}

#[test]
fn hybrid_ablation_sweeps_all_three_modes() {
    let spec = ablation_hybrid(10, &[4, 128]);
    let modes: std::collections::HashSet<&str> =
        spec.rows.iter().map(|(_, c)| c.mode.name()).collect();
    for mode in ["pull", "push", "hybrid"] {
        assert!(modes.contains(mode), "missing {mode}");
    }
    for (label, c) in &spec.rows {
        c.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
    }
    // The write-heavy half runs on the Fig. 7-style constrained broker.
    assert!(spec.rows.iter().any(|(_, c)| c.np == 8 && c.broker_cores == 4));
    assert!(spec.rows.iter().any(|(_, c)| c.np == 2 && c.broker_cores == 16));
}

#[test]
fn writepath_ablation_sweeps_write_and_source_modes() {
    let spec = ablation_writepath(10, &[4, 128]);
    let wmodes: std::collections::HashSet<&str> =
        spec.rows.iter().map(|(_, c)| c.write_mode.name()).collect();
    for mode in ["sync", "pipelined", "sharedmem"] {
        assert!(wmodes.contains(mode), "missing write mode {mode}");
    }
    let smodes: std::collections::HashSet<&str> =
        spec.rows.iter().map(|(_, c)| c.mode.name()).collect();
    for mode in ["pull", "push", "hybrid"] {
        assert!(smodes.contains(mode), "missing source mode {mode}");
    }
    for (label, c) in &spec.rows {
        c.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
        // The Fig. 3 ingestion parameterisation.
        assert_eq!(c.np, 4);
        assert_eq!(c.ns, 8);
        assert_eq!(c.record_size, 100);
    }
    // Both the unloaded and the constrained broker are swept.
    assert!(spec.rows.iter().any(|(_, c)| c.broker_cores == 16));
    assert!(spec.rows.iter().any(|(_, c)| c.broker_cores == 4));
    // 2 NBc x 3 write modes x 3 source modes x 2 chunk sizes.
    assert_eq!(spec.rows.len(), 2 * 3 * 3 * 2);
}

#[test]
fn writepath_ablation_reports_append_latency() {
    let mut spec = ablation_writepath(4, &[32]);
    spec.rows.truncate(2);
    let summaries = run_figure(&spec);
    for s in &summaries {
        assert!(s.report.producers.p50 > 0.0, "ingestion throughput reported");
        assert!(
            s.report.gauge("write_append_latency_us").unwrap_or(0.0) > 0.0,
            "append latency reported"
        );
    }
}

#[test]
fn checkpoint_ablation_sweeps_modes_and_variants() {
    let spec = ablation_checkpoint(10);
    // 3 write modes x 3 source modes x {base, ckpt, fault}.
    assert_eq!(spec.rows.len(), 3 * 3 * 3);
    for (label, c) in &spec.rows {
        c.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
        if c.fault_at_secs > 0 {
            assert!(c.checkpoint_interval_ms > 0, "{label}: faults need checkpoints");
            assert!(c.fault_at_secs < c.duration_secs);
        }
    }
    let smodes: std::collections::HashSet<&str> =
        spec.rows.iter().map(|(_, c)| c.mode.name()).collect();
    for mode in ["pull", "push", "hybrid"] {
        assert!(smodes.contains(mode), "missing source mode {mode}");
    }
    let wmodes: std::collections::HashSet<&str> =
        spec.rows.iter().map(|(_, c)| c.write_mode.name()).collect();
    for mode in ["sync", "pipelined", "sharedmem"] {
        assert!(wmodes.contains(mode), "missing write mode {mode}");
    }
    assert!(spec.rows.iter().any(|(l, c)| l.ends_with("-base") && c.checkpoint_interval_ms == 0));
    assert!(spec.rows.iter().any(|(l, c)| l.ends_with("-fault") && c.fault_at_secs > 0));
}

#[test]
fn checkpoint_ablation_reports_recovery_gauges() {
    let mut spec = ablation_checkpoint(4);
    // Keep one checkpointing row and one faulted row (pull+sync cell).
    spec.rows.retain(|(l, _)| l == "pull+sync-ckpt" || l == "pull+sync-fault");
    assert_eq!(spec.rows.len(), 2);
    let summaries = run_figure(&spec);
    for s in &summaries {
        assert!(s.checkpoints.epochs_completed > 0, "epochs ran");
        assert!(s.report.gauge("checkpoint.epochs").unwrap() > 0.0);
    }
    let faulted = &summaries[1];
    assert_eq!(faulted.checkpoints.recoveries, 1, "the injected fault recovered");
    assert!(faulted.report.gauge("checkpoint.recovery_ms").unwrap() > 0.0);
}

#[test]
fn store_ablation_sweeps_both_backends_across_the_design_space() {
    let spec = ablation_store(10);
    // 2 store modes x 3 write modes x 4 source modes.
    assert_eq!(spec.rows.len(), 2 * 3 * 4);
    let stores: std::collections::HashSet<&str> =
        spec.rows.iter().map(|(_, c)| c.store_mode.name()).collect();
    assert_eq!(stores.len(), 2, "both backends swept");
    for (label, c) in &spec.rows {
        c.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
        if c.store_mode == StoreMode::Durable {
            assert_eq!(c.store_segment_bytes, 1 << 20, "{label}: small segments seal cold files");
            assert!(c.store_dir.is_empty(), "{label}: ephemeral tempdir store");
        }
    }
    assert!(spec.rows.iter().any(|(l, _)| l == "memory+pull+sync"));
    assert!(spec.rows.iter().any(|(l, _)| l == "durable+native+sharedmem"));
}

#[test]
fn store_ablation_durable_row_matches_memory_and_reports_gauges() {
    let mut spec = ablation_store(4);
    spec.rows.retain(|(l, _)| l == "memory+pull+sync" || l == "durable+pull+sync");
    assert_eq!(spec.rows.len(), 2);
    let summaries = run_figure(&spec);
    let (memory, durable) = (&summaries[0], &summaries[1]);
    assert!(
        memory.report.gauge("broker.store_wal_records").is_none(),
        "memory rows export no store gauges"
    );
    assert!(durable.report.gauge("broker.store_wal_records").unwrap() > 0.0);
    assert!(durable.report.gauge("broker.store_segments_flushed").unwrap() > 0.0);
    // Same seed, same modelled work: the backend must not change totals.
    assert_eq!(memory.records_produced, durable.records_produced);
    assert_eq!(memory.records_consumed, durable.records_consumed);
}

#[test]
fn shard_ablation_sweeps_broker_counts_with_live_rebalance_rows() {
    let spec = ablation_shard(10);
    // 3 broker counts x {pull, push} + 2 rebalance rows at bc=3.
    assert_eq!(spec.rows.len(), 3 * 2 + 2);
    for (label, c) in &spec.rows {
        c.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
        assert_eq!(c.ns % c.broker_count, 0, "{label}: partitions split evenly");
        assert_eq!(c.nc % c.broker_count, 0, "{label}: consumer spans stay on one broker");
    }
    let counts: std::collections::HashSet<usize> =
        spec.rows.iter().map(|(_, c)| c.broker_count).collect();
    assert_eq!(counts, [1, 2, 3].into_iter().collect());
    for (label, c) in spec.rows.iter().filter(|(l, _)| l.contains("rebal")) {
        assert_eq!(c.broker_count, 3, "{label}");
        assert_eq!(c.replication_factor, 2, "{label}: hand-off needs a live backup");
        assert!(
            c.rebalance_at_secs > 0 && c.rebalance_at_secs < c.duration_secs,
            "{label}: rebalance lands mid-run"
        );
    }
}

#[test]
fn hotpath_null_or_zero_baseline_scans_as_absent() {
    let dir = std::env::temp_dir().join(format!("zs-hotpath-baseline-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.json");
    // The committed seed shape: field present but never measured.
    std::fs::write(&path, "{\n  \"cluster_events_per_s\": null,\n  \"cells\": []\n}\n").unwrap();
    assert_eq!(hotpath::read_baseline(&path), None, "null is not a baseline");
    std::fs::write(&path, "{ \"cluster_events_per_s\": 0.000 }").unwrap();
    assert_eq!(hotpath::read_baseline(&path), None, "zero is not a baseline");
    std::fs::write(&path, "{ \"cluster_events_per_s\": 123456.789 }").unwrap();
    assert_eq!(hotpath::read_baseline(&path), Some(123456.789));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hotpath_json_roundtrips_the_gate_number() {
    let report = hotpath::HotpathReport {
        engine_events_per_s: 1e6,
        cluster_events_per_s: 2_500_000.0,
        cluster_virt_per_wall: 10.0,
        baseline_cluster_events_per_s: None,
        cells: Vec::new(),
    };
    assert!(report.speedup_vs_baseline().is_none(), "no baseline, no speedup");
    let dir = std::env::temp_dir().join(format!("zs-hotpath-roundtrip-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.json");
    hotpath::write_json(&path, &report).unwrap();
    assert_eq!(hotpath::read_baseline(&path), Some(2_500_000.0));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn table2_lists_all_benchmarks() {
    let t = table2();
    for fig in ["Fig.4", "Fig.5", "Fig.6", "Fig.7", "Fig.8", "Fig.9"] {
        assert!(t.contains(fig), "missing {fig}");
    }
}

#[test]
fn a_small_figure_actually_runs() {
    let mut spec = fig8(4);
    spec.rows.truncate(2);
    let summaries = run_figure(&spec);
    assert_eq!(summaries.len(), 2);
    for s in &summaries {
        assert!(s.report.producers.p50 > 0.0);
    }
}
