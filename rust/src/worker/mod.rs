//! The Flink-like processing worker: operator tasks, bounded queues,
//! credit-based backpressure.
//!
//! §IV-A: a worker hosts `NFs` slots; sources, sinks and other operators
//! deploy on slots and exchange batches through queues. Flink's actual
//! flow control is credit-based; so is ours: every upstream→downstream
//! pair starts with `queue_cap` credits, an upstream spends one per batch
//! and the downstream returns it after *processing* the batch. A slow
//! operator therefore stalls its upstreams — which is exactly the
//! backpressure the paper's push design must preserve (§III).
//!
//! [`OperatorTask`] is one slot-resident task thread: a serial loop over
//! its input queue driving an operator chain (chained operators execute
//! in the same task, Fig. 1's S1→Op3 case).

#[cfg(test)]
mod tests;

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

use crate::config::CostModel;
use crate::metrics::{Class, SharedMetrics};
use crate::ops::{OpOutput, Operator};
use crate::proto::{Batch, Msg};
use crate::sim::{Actor, ActorId, Ctx, Time, SECOND};

/// Maps global task index -> actor id (filled by the launcher).
#[derive(Debug, Default)]
pub struct TaskRegistry {
    actors: Vec<Option<ActorId>>,
}

pub type SharedRegistry = Rc<RefCell<TaskRegistry>>;

impl TaskRegistry {
    pub fn shared() -> SharedRegistry {
        Rc::new(RefCell::new(Self::default()))
    }

    pub fn register(&mut self, task_idx: usize, actor: ActorId) {
        if self.actors.len() <= task_idx {
            self.actors.resize(task_idx + 1, None);
        }
        assert!(self.actors[task_idx].is_none(), "task {task_idx} registered twice");
        self.actors[task_idx] = Some(actor);
    }

    pub fn actor_of(&self, task_idx: usize) -> ActorId {
        self.actors[task_idx].unwrap_or_else(|| panic!("task {task_idx} not registered"))
    }

    pub fn len(&self) -> usize {
        self.actors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actors.is_empty()
    }
}

/// Credit ledger an upstream keeps toward its downstream targets.
#[derive(Debug)]
pub struct CreditLedger {
    credits: HashMap<usize, usize>,
    cap: usize,
}

impl CreditLedger {
    pub fn new(targets: &[usize], cap: usize) -> Self {
        assert!(cap > 0);
        Self { credits: targets.iter().map(|&t| (t, cap)).collect(), cap }
    }

    pub fn has(&self, target: usize) -> bool {
        self.credits.get(&target).copied().unwrap_or(0) > 0
    }

    pub fn spend(&mut self, target: usize) {
        let c = self.credits.get_mut(&target).expect("known target");
        assert!(*c > 0, "spending a credit we do not have (task {target})");
        *c -= 1;
    }

    pub fn refund(&mut self, target: usize) {
        let c = self.credits.get_mut(&target).expect("known target");
        *c += 1;
        assert!(*c <= self.cap, "credit overflow from task {target}");
    }
}

/// Wiring for one operator task.
pub struct TaskParams {
    /// Global task index (registry key; also the metrics entity).
    pub task_idx: usize,
    /// Credits granted per upstream (input queue capacity in batches).
    pub queue_cap: usize,
    /// Credits toward each downstream target this task emits to.
    pub downstream: Vec<usize>,
    /// Slide tick period for windowed chains (ns); `SECOND` in the paper.
    pub tick_ns: Time,
    pub cost: CostModel,
}

/// One slot-resident task: input queue + operator chain + credit flow.
pub struct OperatorTask {
    params: TaskParams,
    chain: Vec<Box<dyn Operator>>,
    inbox: VecDeque<Batch>,
    /// Emits waiting for downstream credits.
    pending_emits: VecDeque<(usize, Batch)>,
    ledger: CreditLedger,
    busy: bool,
    registry: SharedRegistry,
    metrics: SharedMetrics,
    batches_processed: u64,
    /// Peak input-queue depth (backpressure diagnostics).
    inbox_peak: usize,
}

impl OperatorTask {
    pub fn new(
        params: TaskParams,
        chain: Vec<Box<dyn Operator>>,
        registry: SharedRegistry,
        metrics: SharedMetrics,
    ) -> Self {
        assert!(!chain.is_empty(), "a task needs at least one operator");
        let ledger = CreditLedger::new(&params.downstream, params.queue_cap);
        Self {
            params,
            chain,
            inbox: VecDeque::new(),
            pending_emits: VecDeque::new(),
            ledger,
            busy: false,
            registry,
            metrics,
            batches_processed: 0,
            inbox_peak: 0,
        }
    }

    fn chain_cost(&self, batch: &Batch) -> Time {
        self.chain.iter().map(|op| op.cost(batch, &self.params.cost)).sum::<Time>()
            + self.params.cost.queue_hop_ns
    }

    /// Start processing the head batch if idle and not emit-blocked.
    fn try_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.busy || !self.pending_emits.is_empty() {
            return;
        }
        if let Some(batch) = self.inbox.front() {
            let cost = self.chain_cost(batch);
            self.busy = true;
            ctx.send_self_in(cost, Msg::JobDone(0));
        }
    }

    fn flush_emits(&mut self, ctx: &mut Ctx<'_, Msg>) {
        while let Some((target, _)) = self.pending_emits.front() {
            if !self.ledger.has(*target) {
                return;
            }
            let (target, batch) = self.pending_emits.pop_front().expect("peeked");
            self.send_batch(target, batch, ctx);
        }
    }

    fn send_batch(&mut self, target: usize, batch: Batch, ctx: &mut Ctx<'_, Msg>) {
        self.ledger.spend(target);
        let actor = self.registry.borrow().actor_of(target);
        ctx.send_in(self.params.cost.queue_hop_ns, actor, Msg::Data(batch));
    }

    fn route(&mut self, out: OpOutput, ctx: &mut Ctx<'_, Msg>) {
        if out.tuples_logged > 0 {
            self.metrics.borrow_mut().record(
                Class::ConsumerTuples,
                self.params.task_idx,
                ctx.now(),
                out.tuples_logged,
            );
        }
        for (target, batch) in out.emits {
            if self.pending_emits.is_empty() && self.ledger.has(target) {
                self.send_batch(target, batch, ctx);
            } else {
                self.pending_emits.push_back((target, batch));
            }
        }
    }

    fn on_done(&mut self, ctx: &mut Ctx<'_, Msg>) {
        debug_assert!(self.busy);
        self.busy = false;
        let batch = self.inbox.pop_front().expect("processing an inbox batch");
        let from_upstream = batch.from_task;
        let me = self.params.task_idx;
        let mut out = OpOutput::default();
        let mut current = batch;
        let chain_len = self.chain.len();
        for (i, op) in self.chain.iter_mut().enumerate() {
            let mut step = OpOutput::default();
            let passthrough = current.clone();
            op.apply(current, me, &mut step)
                .unwrap_or_else(|e| panic!("task {me} op {}: {e:#}", i));
            out.tuples_logged += step.tuples_logged;
            if i + 1 == chain_len {
                out.emits = step.emits;
                break;
            }
            // Chained operators hand at most one batch to the next stage;
            // pass-through loggers (count/filter) forward the input batch,
            // multi-emit stages (keyBy exchanges) must end a chain.
            match step.emits.len() {
                0 => current = passthrough,
                1 => current = step.emits.pop().expect("len checked").1,
                n => panic!("task {me}: chained op emits {n} batches mid-chain"),
            }
        }
        self.batches_processed += 1;
        self.route(out, ctx);
        // Return the credit to the upstream that sent the processed batch.
        let upstream_actor = self.registry.borrow().actor_of(from_upstream);
        ctx.send(upstream_actor, Msg::Credit { to_upstream_task: self.params.task_idx });
        self.try_start(ctx);
    }

    pub fn batches_processed(&self) -> u64 {
        self.batches_processed
    }

    pub fn inbox_peak(&self) -> usize {
        self.inbox_peak
    }

    /// Borrow an operator in the chain (end-of-run inspection).
    pub fn op(&self, idx: usize) -> &dyn Operator {
        self.chain[idx].as_ref()
    }

    /// Downcast an operator in the chain to its concrete type.
    pub fn op_as<T: 'static>(&mut self, idx: usize) -> Option<&mut T> {
        self.chain[idx].as_any_mut().downcast_mut::<T>()
    }
}

impl Actor<Msg> for OperatorTask {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.chain.iter().any(|op| op.wants_ticks()) {
            let tick = if self.params.tick_ns > 0 { self.params.tick_ns } else { SECOND };
            ctx.send_self_in(tick, Msg::Timer(0));
        }
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Data(batch) => {
                self.inbox.push_back(batch);
                self.inbox_peak = self.inbox_peak.max(self.inbox.len());
                self.try_start(ctx);
            }
            Msg::JobDone(_) => self.on_done(ctx),
            Msg::Credit { to_upstream_task } => {
                self.ledger.refund(to_upstream_task);
                self.flush_emits(ctx);
                self.try_start(ctx);
            }
            Msg::Timer(_) => {
                let mut out = OpOutput::default();
                for op in self.chain.iter_mut() {
                    if op.wants_ticks() {
                        op.on_tick(&mut out)
                            .unwrap_or_else(|e| panic!("task {} tick: {e:#}", self.params.task_idx));
                    }
                }
                self.route(out, ctx);
                let tick = if self.params.tick_ns > 0 { self.params.tick_ns } else { SECOND };
                ctx.send_self_in(tick, Msg::Timer(0));
            }
            other => panic!("task {}: unexpected {other:?}", self.params.task_idx),
        }
    }

    fn label(&self) -> String {
        format!("task#{}({})", self.params.task_idx, self.chain[0].name())
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}
