//! The Flink-like processing worker: operator tasks, bounded queues,
//! credit-based backpressure, aligned checkpoint barriers.
//!
//! §IV-A: a worker hosts `NFs` slots; sources, sinks and other operators
//! deploy on slots and exchange batches through queues. Flink's actual
//! flow control is credit-based; so is ours: every upstream→downstream
//! pair starts with `queue_cap` credits, an upstream spends one per batch
//! and the downstream returns it after *processing* the batch. A slow
//! operator therefore stalls its upstreams — which is exactly the
//! backpressure the paper's push design must preserve (§III).
//!
//! [`OperatorTask`] is one slot-resident task thread: a serial loop over
//! its input queue driving an operator chain (chained operators execute
//! in the same task, Fig. 1's S1→Op3 case).
//!
//! ## Checkpoint barriers & recovery
//!
//! When checkpointing is on (see [`crate::checkpoint`]), barriers flow
//! in-band through the same channels as data. A task *aligns*: it keeps
//! processing channels whose barrier has not arrived, buffers post-barrier
//! batches from channels whose barrier has (they belong to the next
//! epoch), and — once every upstream's barrier arrived and the inbox
//! drained — snapshots its operator chain, acks the coordinator and
//! forwards the barrier downstream behind any still-pending emits.
//! Barriers consume no credits (they carry no payload); the in-band
//! ordering is what matters.
//!
//! Recovery is a global rollback: on [`Msg::Restore`] the task wipes its
//! volatile state (inbox, pending emits, ledger), restores its operators
//! from the latest completed snapshot (or their pristine state, captured
//! at construction, if none completed yet) and adopts the new incarnation
//! number. Everything in flight from the old incarnation — batches,
//! credits, job completions, tick timers — identifies itself by `inc` tag
//! and is dropped on receipt.

#[cfg(test)]
mod tests;

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

use crate::checkpoint::{SharedCheckpoint, TaskSnapshot};
use crate::config::CostModel;
use crate::metrics::{Class, SharedMetrics};
use crate::ops::{OpOutput, OpState, Operator};
use crate::proto::{Batch, Msg};
use crate::sim::{Actor, ActorId, Ctx, Time, SECOND};

/// Maps global task index -> actor id (filled by the launcher).
#[derive(Debug, Default)]
pub struct TaskRegistry {
    actors: Vec<Option<ActorId>>,
}

pub type SharedRegistry = Rc<RefCell<TaskRegistry>>;

impl TaskRegistry {
    pub fn shared() -> SharedRegistry {
        Rc::new(RefCell::new(Self::default()))
    }

    pub fn register(&mut self, task_idx: usize, actor: ActorId) {
        if self.actors.len() <= task_idx {
            self.actors.resize(task_idx + 1, None);
        }
        assert!(self.actors[task_idx].is_none(), "task {task_idx} registered twice");
        self.actors[task_idx] = Some(actor);
    }

    pub fn actor_of(&self, task_idx: usize) -> ActorId {
        self.actors[task_idx].unwrap_or_else(|| panic!("task {task_idx} not registered"))
    }

    pub fn len(&self) -> usize {
        self.actors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actors.is_empty()
    }
}

/// Credit ledger an upstream keeps toward its downstream targets.
#[derive(Debug)]
pub struct CreditLedger {
    credits: HashMap<usize, usize>,
    cap: usize,
}

impl CreditLedger {
    pub fn new(targets: &[usize], cap: usize) -> Self {
        assert!(cap > 0);
        Self { credits: targets.iter().map(|&t| (t, cap)).collect(), cap }
    }

    pub fn has(&self, target: usize) -> bool {
        self.credits.get(&target).copied().unwrap_or(0) > 0
    }

    pub fn spend(&mut self, target: usize) {
        let c = self.credits.get_mut(&target).expect("known target");
        assert!(*c > 0, "spending a credit we do not have (task {target})");
        *c -= 1;
    }

    pub fn refund(&mut self, target: usize) {
        let c = self.credits.get_mut(&target).expect("known target");
        *c += 1;
        assert!(*c <= self.cap, "credit overflow from task {target}");
    }
}

/// Wiring for one operator task.
pub struct TaskParams {
    /// Global task index (registry key; also the metrics entity).
    pub task_idx: usize,
    /// Credits granted per upstream (input queue capacity in batches).
    pub queue_cap: usize,
    /// Credits toward each downstream target this task emits to.
    pub downstream: Vec<usize>,
    /// Upstream task indices feeding this task (sources for stage 0) —
    /// the channel set a checkpoint barrier aligns over.
    pub upstream: Vec<usize>,
    /// Slide tick period for windowed chains (ns); `SECOND` in the paper.
    pub tick_ns: Time,
    pub cost: CostModel,
    /// Checkpoint blackboard (`None` = checkpointing disabled).
    pub checkpoint: Option<SharedCheckpoint>,
}

/// An element of the emit queue: a credited batch toward one target, or an
/// uncredited barrier broadcast parked behind earlier emits (in-band
/// ordering: the barrier must not overtake batches produced before the
/// snapshot).
enum Emit {
    Batch(usize, Batch),
    Barrier(u64),
}

/// In-flight barrier alignment.
struct Alignment {
    epoch: u64,
    /// Upstreams whose barrier arrived.
    seen: Vec<usize>,
    /// Post-barrier batches from `seen` channels, held for the next epoch.
    buffered: VecDeque<Batch>,
    started: Time,
}

/// One slot-resident task: input queue + operator chain + credit flow.
pub struct OperatorTask {
    params: TaskParams,
    chain: Vec<Box<dyn Operator>>,
    /// Pristine per-operator state, captured at construction — the restore
    /// point before any checkpoint completes.
    initial: Vec<OpState>,
    inbox: VecDeque<Batch>,
    /// Emits waiting for downstream credits (and parked barriers).
    pending_emits: VecDeque<Emit>,
    ledger: CreditLedger,
    busy: bool,
    /// Recovery incarnation; stale-tagged messages are dropped.
    inc: u64,
    /// True between an injected fault and the restore — a dead process
    /// ignores everything but `Restore`.
    failed: bool,
    /// Barriers with `epoch <= epoch_floor` are stale (completed or
    /// aborted before the last restore).
    epoch_floor: u64,
    align: Option<Alignment>,
    registry: SharedRegistry,
    metrics: SharedMetrics,
    batches_processed: u64,
    /// Peak input-queue depth (backpressure diagnostics).
    inbox_peak: usize,
    /// Pooled operator output, reused across batches: `route` drains the
    /// emit vector instead of dropping it, so steady state allocates
    /// nothing per batch.
    out_pool: OpOutput,
}

impl OperatorTask {
    pub fn new(
        params: TaskParams,
        chain: Vec<Box<dyn Operator>>,
        registry: SharedRegistry,
        metrics: SharedMetrics,
    ) -> Self {
        assert!(!chain.is_empty(), "a task needs at least one operator");
        let ledger = CreditLedger::new(&params.downstream, params.queue_cap);
        let initial = chain.iter().map(|op| op.snapshot()).collect();
        Self {
            params,
            chain,
            initial,
            inbox: VecDeque::new(),
            pending_emits: VecDeque::new(),
            ledger,
            busy: false,
            inc: 0,
            failed: false,
            epoch_floor: 0,
            align: None,
            registry,
            metrics,
            batches_processed: 0,
            inbox_peak: 0,
            out_pool: OpOutput::default(),
        }
    }

    fn chain_cost(&self, batch: &Batch) -> Time {
        self.chain.iter().map(|op| op.cost(batch, &self.params.cost)).sum::<Time>()
            + self.params.cost.queue_hop_ns
    }

    fn tick_period(&self) -> Time {
        if self.params.tick_ns > 0 { self.params.tick_ns } else { SECOND }
    }

    /// Start processing the head batch if idle and not emit-blocked.
    fn try_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.busy || !self.pending_emits.is_empty() {
            return;
        }
        if let Some(batch) = self.inbox.front() {
            let cost = self.chain_cost(batch);
            self.busy = true;
            ctx.send_self_in(cost, Msg::JobDone(self.inc));
        }
    }

    fn flush_emits(&mut self, ctx: &mut Ctx<'_, Msg>) {
        while let Some(head) = self.pending_emits.front() {
            match head {
                Emit::Barrier(_) => {
                    let Some(Emit::Barrier(epoch)) = self.pending_emits.pop_front() else {
                        unreachable!("peeked")
                    };
                    self.broadcast_barrier(epoch, ctx);
                }
                Emit::Batch(target, _) => {
                    if !self.ledger.has(*target) {
                        return;
                    }
                    let Some(Emit::Batch(target, batch)) = self.pending_emits.pop_front() else {
                        unreachable!("peeked")
                    };
                    self.send_batch(target, batch, ctx);
                }
            }
        }
    }

    fn send_batch(&mut self, target: usize, mut batch: Batch, ctx: &mut Ctx<'_, Msg>) {
        self.ledger.spend(target);
        batch.inc = self.inc;
        let actor = self.registry.borrow().actor_of(target);
        ctx.send_in(self.params.cost.queue_hop_ns, actor, Msg::Data(batch));
    }

    /// Forward barrier `epoch` on every output channel (no credits: the
    /// barrier carries no payload; same queue-hop delay keeps it in-band).
    fn broadcast_barrier(&mut self, epoch: u64, ctx: &mut Ctx<'_, Msg>) {
        let me = self.params.task_idx;
        for &target in &self.params.downstream {
            let actor = self.registry.borrow().actor_of(target);
            ctx.send_in(
                self.params.cost.queue_hop_ns,
                actor,
                Msg::Barrier { epoch, from_task: me },
            );
        }
    }

    /// Log + forward one batch's operator output, draining `out` in place
    /// (the caller's buffer keeps its capacity for the next batch).
    fn route(&mut self, out: &mut OpOutput, ctx: &mut Ctx<'_, Msg>) {
        if out.tuples_logged > 0 {
            self.metrics.borrow_mut().record(
                Class::ConsumerTuples,
                self.params.task_idx,
                ctx.now(),
                out.tuples_logged,
            );
        }
        out.tuples_logged = 0;
        for (target, batch) in out.emits.drain(..) {
            if self.pending_emits.is_empty() && self.ledger.has(target) {
                self.send_batch(target, batch, ctx);
            } else {
                self.pending_emits.push_back(Emit::Batch(target, batch));
            }
        }
    }

    fn on_data(&mut self, batch: Batch, ctx: &mut Ctx<'_, Msg>) {
        if let Some(a) = &mut self.align {
            if a.seen.contains(&batch.from_task) {
                // Post-barrier input on an already-barriered channel: it
                // belongs to the next epoch — hold it until the snapshot.
                a.buffered.push_back(batch);
                return;
            }
        }
        self.inbox.push_back(batch);
        self.inbox_peak = self.inbox_peak.max(self.inbox.len());
        self.try_start(ctx);
    }

    fn on_barrier(&mut self, epoch: u64, from_task: usize, ctx: &mut Ctx<'_, Msg>) {
        if self.params.checkpoint.is_none() || epoch <= self.epoch_floor {
            return; // checkpointing off, or a stale barrier from before a restore
        }
        match &mut self.align {
            None => {
                self.align = Some(Alignment {
                    epoch,
                    seen: vec![from_task],
                    buffered: VecDeque::new(),
                    started: ctx.now(),
                });
            }
            Some(a) => {
                if a.epoch != epoch {
                    return; // barrier from an aborted earlier wave
                }
                if !a.seen.contains(&from_task) {
                    a.seen.push(from_task);
                }
            }
        }
        self.try_complete_alignment(ctx);
    }

    /// Complete the alignment once every upstream's barrier arrived AND all
    /// pre-barrier input drained — the snapshot must reflect exactly the
    /// pre-barrier records.
    fn try_complete_alignment(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let ready = match &self.align {
            Some(a) => a.seen.len() >= self.params.upstream.len(),
            None => false,
        };
        if !ready || !self.inbox.is_empty() || self.busy {
            return;
        }
        let a = self.align.take().expect("checked above");
        self.epoch_floor = a.epoch;
        let snap = TaskSnapshot { ops: self.chain.iter().map(|op| op.snapshot()).collect() };
        let cp = self.params.checkpoint.as_ref().expect("aligning implies checkpointing");
        let coordinator = {
            let mut c = cp.borrow_mut();
            c.put_task(a.epoch, ctx.self_id(), snap);
            c.note_alignment(ctx.now() - a.started);
            c.coordinator
        };
        if let Some(coordinator) = coordinator {
            ctx.send_in(
                self.params.cost.notify_ns,
                coordinator,
                Msg::BarrierAck { epoch: a.epoch, from: ctx.self_id() },
            );
        }
        // The barrier goes out behind everything already produced; output
        // from the buffered (post-barrier) batches will follow it.
        if self.pending_emits.is_empty() {
            self.broadcast_barrier(a.epoch, ctx);
        } else {
            self.pending_emits.push_back(Emit::Barrier(a.epoch));
        }
        for batch in a.buffered {
            self.inbox.push_back(batch);
        }
        self.inbox_peak = self.inbox_peak.max(self.inbox.len());
        self.try_start(ctx);
    }

    fn on_done(&mut self, ctx: &mut Ctx<'_, Msg>) {
        debug_assert!(self.busy);
        self.busy = false;
        let batch = self.inbox.pop_front().expect("processing an inbox batch");
        let from_upstream = batch.from_task;
        let me = self.params.task_idx;
        // The pooled output buffer: taken for the duration of the batch,
        // returned (drained, capacity intact) after routing.
        let mut out = std::mem::take(&mut self.out_pool);
        debug_assert!(out.emits.is_empty() && out.tuples_logged == 0);
        let mut current = batch;
        let chain_len = self.chain.len();
        for (i, op) in self.chain.iter_mut().enumerate() {
            if i + 1 == chain_len {
                // The final (usually only) operator writes straight into
                // the pooled buffer — no passthrough clone, no per-op
                // scratch on the single-operator fast path.
                op.apply(current, me, &mut out)
                    .unwrap_or_else(|e| panic!("task {me} op {}: {e:#}", i));
                break;
            }
            // Chained operators hand at most one batch to the next stage;
            // pass-through loggers (count/filter) forward the input batch
            // (a cheap clone: the chunks are shared, see `ChunkList`),
            // multi-emit stages (keyBy exchanges) must end a chain.
            let mut step = OpOutput::default();
            let passthrough = current.clone();
            op.apply(current, me, &mut step)
                .unwrap_or_else(|e| panic!("task {me} op {}: {e:#}", i));
            out.tuples_logged += step.tuples_logged;
            match step.emits.len() {
                0 => current = passthrough,
                1 => current = step.emits.pop().expect("len checked").1,
                n => panic!("task {me}: chained op emits {n} batches mid-chain"),
            }
        }
        self.batches_processed += 1;
        self.route(&mut out, ctx);
        self.out_pool = out;
        if self.metrics.borrow().tracer.enabled() {
            // Closes the span the upstream source opened for this batch's
            // chunk (marker FIFO keyed by the (from, to) channel).
            self.metrics.borrow_mut().tracer.on_emit(from_upstream, me, ctx.now());
        }
        // Return the credit to the upstream that sent the processed batch.
        let upstream_actor = self.registry.borrow().actor_of(from_upstream);
        ctx.send(
            upstream_actor,
            Msg::Credit { to_upstream_task: self.params.task_idx, inc: self.inc },
        );
        // The inbox may just have drained below an armed alignment.
        self.try_complete_alignment(ctx);
        self.try_start(ctx);
    }

    /// An injected fault: the process dies. Volatile state is gone; the
    /// failure detector (modelled as an instant local notice) alerts the
    /// coordinator; everything but `Restore` is ignored until then.
    fn on_fault(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.failed = true;
        self.busy = false;
        self.inbox.clear();
        self.pending_emits.clear();
        self.align = None;
        let cp = self
            .params
            .checkpoint
            .as_ref()
            .unwrap_or_else(|| panic!("task {} faulted without checkpointing", self.params.task_idx));
        let coordinator = cp.borrow().coordinator.expect("coordinator wired before faults");
        ctx.send_in(
            self.params.cost.notify_ns,
            coordinator,
            Msg::FailureDetected { from: ctx.self_id() },
        );
    }

    /// Global rollback: adopt the new incarnation, reset volatile state,
    /// restore the operator chain from the latest completed checkpoint
    /// (or its pristine construction state) and resume.
    fn on_restore(&mut self, inc: u64, epoch_floor: u64, ctx: &mut Ctx<'_, Msg>) {
        self.inc = inc;
        self.epoch_floor = self.epoch_floor.max(epoch_floor);
        self.failed = false;
        self.busy = false;
        self.inbox.clear();
        self.pending_emits.clear();
        self.align = None;
        self.ledger = CreditLedger::new(&self.params.downstream, self.params.queue_cap);
        let cp = self.params.checkpoint.as_ref().expect("restore implies checkpointing");
        let states = cp
            .borrow()
            .task_snapshot(ctx.self_id())
            .map(|s| s.ops)
            .unwrap_or_else(|| self.initial.clone());
        assert_eq!(states.len(), self.chain.len(), "snapshot shape matches the chain");
        for (op, state) in self.chain.iter_mut().zip(states.iter()) {
            op.restore(state);
        }
        // Restart the tick chain under the new incarnation (the old chain's
        // stale tags die on receipt).
        if self.chain.iter().any(|op| op.wants_ticks()) {
            ctx.send_self_in(self.tick_period(), Msg::Timer(self.inc));
        }
        let coordinator = cp.borrow().coordinator.expect("coordinator wired");
        ctx.send_in(
            self.params.cost.notify_ns,
            coordinator,
            Msg::RestoreAck { from: ctx.self_id() },
        );
    }

    fn on_tick(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let mut out = std::mem::take(&mut self.out_pool);
        for op in self.chain.iter_mut() {
            if op.wants_ticks() {
                op.on_tick(&mut out)
                    .unwrap_or_else(|e| panic!("task {} tick: {e:#}", self.params.task_idx));
            }
        }
        self.route(&mut out, ctx);
        self.out_pool = out;
        ctx.send_self_in(self.tick_period(), Msg::Timer(self.inc));
    }

    pub fn batches_processed(&self) -> u64 {
        self.batches_processed
    }

    pub fn inbox_peak(&self) -> usize {
        self.inbox_peak
    }

    /// Borrow an operator in the chain (end-of-run inspection).
    pub fn op(&self, idx: usize) -> &dyn Operator {
        self.chain[idx].as_ref()
    }

    /// Downcast an operator in the chain to its concrete type.
    pub fn op_as<T: 'static>(&mut self, idx: usize) -> Option<&mut T> {
        self.chain[idx].as_any_mut().downcast_mut::<T>()
    }
}

impl Actor<Msg> for OperatorTask {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.chain.iter().any(|op| op.wants_ticks()) {
            ctx.send_self_in(self.tick_period(), Msg::Timer(self.inc));
        }
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if self.failed {
            // A dead process: only the restore resurrects it; everything
            // else in flight is lost with the incarnation.
            if let Msg::Restore { inc, epoch_floor } = msg {
                self.on_restore(inc, epoch_floor, ctx);
            }
            return;
        }
        match msg {
            Msg::Data(batch) => {
                if batch.inc != self.inc {
                    return; // in flight across a rollback: replayed from cursors
                }
                self.on_data(batch, ctx);
            }
            Msg::JobDone(tag) => {
                if tag == self.inc {
                    self.on_done(ctx);
                }
            }
            Msg::Credit { to_upstream_task, inc } => {
                if inc != self.inc {
                    return; // credit for a pre-rollback batch: ledger was reset
                }
                self.ledger.refund(to_upstream_task);
                self.flush_emits(ctx);
                self.try_start(ctx);
            }
            Msg::Timer(tag) => {
                if tag == self.inc {
                    self.on_tick(ctx);
                }
            }
            Msg::Barrier { epoch, from_task } => self.on_barrier(epoch, from_task, ctx),
            Msg::Fault { .. } => self.on_fault(ctx),
            Msg::Restore { inc, epoch_floor } => self.on_restore(inc, epoch_floor, ctx),
            other => panic!("task {}: unexpected {other:?}", self.params.task_idx),
        }
    }

    fn label(&self) -> String {
        format!("task#{}({})", self.params.task_idx, self.chain[0].name())
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}
