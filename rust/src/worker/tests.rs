//! OperatorTask tests: queueing, credits, backpressure, chaining, ticks.

use std::cell::RefCell;
use std::rc::Rc;

use super::*;
use crate::metrics::MetricsHub;
use crate::ops::{CountOp, OpOutput, Operator};
use crate::sim::{ActorId, Engine};

/// Upstream stub: sends N batches as fast as credits allow; records credit
/// returns.
struct Feeder {
    my_task: usize,
    target_task: usize,
    to_send: u64,
    tuples_per_batch: u64,
    ledger: CreditLedger,
    registry: SharedRegistry,
    credits_seen: Rc<RefCell<u64>>,
}

impl crate::sim::Actor<Msg> for Feeder {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.pump(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if let Msg::Credit { to_upstream_task } = msg {
            *self.credits_seen.borrow_mut() += 1;
            self.ledger.refund(to_upstream_task);
            self.pump(ctx);
        }
    }
}

impl Feeder {
    fn pump(&mut self, ctx: &mut Ctx<'_, Msg>) {
        while self.to_send > 0 && self.ledger.has(self.target_task) {
            self.ledger.spend(self.target_task);
            self.to_send -= 1;
            let actor = self.registry.borrow().actor_of(self.target_task);
            ctx.send(
                actor,
                Msg::Data(Batch {
                    from_task: self.my_task,
                    tuples: self.tuples_per_batch,
                    bytes: self.tuples_per_batch * 100,
                    chunks: Vec::new(),
                    hist: None,
                }),
            );
        }
    }
}

/// Slow terminal operator with a fixed per-batch cost.
struct SlowOp {
    per_batch: Time,
    seen: u64,
}

impl Operator for SlowOp {
    fn name(&self) -> &'static str {
        "slow"
    }
    fn cost(&self, _b: &Batch, _c: &CostModel) -> Time {
        self.per_batch
    }
    fn apply(&mut self, b: Batch, _f: usize, out: &mut OpOutput) -> Result<(), anyhow::Error> {
        self.seen += 1;
        out.tuples_logged = b.tuples;
        Ok(())
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

struct Rig {
    engine: Engine<Msg>,
    task: ActorId,
    metrics: SharedMetrics,
    credits_seen: Rc<RefCell<u64>>,
}

fn rig(n_batches: u64, queue_cap: usize, per_batch_ns: Time) -> Rig {
    let mut engine = Engine::new(1);
    let metrics = MetricsHub::shared();
    let registry = TaskRegistry::shared();
    let task = engine.add_actor(Box::new(OperatorTask::new(
        TaskParams {
            task_idx: 1,
            queue_cap,
            downstream: vec![],
            tick_ns: crate::sim::SECOND,
            cost: CostModel::default(),
        },
        vec![Box::new(SlowOp { per_batch: per_batch_ns, seen: 0 })],
        registry.clone(),
        metrics.clone(),
    )));
    registry.borrow_mut().register(1, task);
    let credits_seen = Rc::new(RefCell::new(0u64));
    let feeder = engine.add_actor(Box::new(Feeder {
        my_task: 0,
        target_task: 1,
        to_send: n_batches,
        tuples_per_batch: 10,
        ledger: CreditLedger::new(&[1], queue_cap),
        registry: registry.clone(),
        credits_seen: credits_seen.clone(),
    }));
    registry.borrow_mut().register(0, feeder);
    Rig { engine, task, metrics, credits_seen }
}

#[test]
fn processes_all_batches_and_returns_credits() {
    let mut r = rig(20, 4, 1000);
    r.engine.run_to_quiescence();
    let t = r.engine.actor_as::<OperatorTask>(r.task).unwrap();
    assert_eq!(t.batches_processed(), 20);
    assert_eq!(*r.credits_seen.borrow(), 20);
    assert_eq!(
        r.metrics.borrow().total(crate::metrics::Class::ConsumerTuples),
        200
    );
}

#[test]
fn queue_depth_bounded_by_credits() {
    let mut r = rig(100, 3, 10_000);
    r.engine.run_to_quiescence();
    let t = r.engine.actor_as::<OperatorTask>(r.task).unwrap();
    assert_eq!(t.batches_processed(), 100);
    assert!(t.inbox_peak() <= 3, "credits cap the inbox: {}", t.inbox_peak());
}

#[test]
fn serial_processing_takes_cost_times_batches() {
    let mut r = rig(10, 2, 50_000);
    r.engine.run_to_quiescence();
    // 10 batches x 50us each, serially
    assert!(r.engine.now() >= 500_000, "serial task time: {}", r.engine.now());
}

#[test]
fn credit_ledger_protocol() {
    let mut l = CreditLedger::new(&[5, 6], 2);
    assert!(l.has(5));
    l.spend(5);
    l.spend(5);
    assert!(!l.has(5));
    assert!(l.has(6), "targets are independent");
    l.refund(5);
    assert!(l.has(5));
}

#[test]
#[should_panic(expected = "credit overflow")]
fn over_refund_is_a_bug() {
    let mut l = CreditLedger::new(&[1], 1);
    l.refund(1);
}

#[test]
#[should_panic(expected = "spending a credit")]
fn overspend_is_a_bug() {
    let mut l = CreditLedger::new(&[1], 1);
    l.spend(1);
    l.spend(1);
}

#[test]
fn registry_rejects_double_registration() {
    let reg = TaskRegistry::shared();
    reg.borrow_mut().register(0, ActorId(1));
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        reg.borrow_mut().register(0, ActorId(2));
    }));
    assert!(result.is_err());
}

#[test]
fn chained_operators_share_one_task() {
    // Chain: count -> count. Both see the batch; cost adds up.
    let mut engine = Engine::new(1);
    let metrics = MetricsHub::shared();
    let registry = TaskRegistry::shared();
    let task = engine.add_actor(Box::new(OperatorTask::new(
        TaskParams {
            task_idx: 1,
            queue_cap: 4,
            downstream: vec![],
            tick_ns: crate::sim::SECOND,
            cost: CostModel::default(),
        },
        vec![Box::new(CountOp::default()), Box::new(CountOp::default())],
        registry.clone(),
        metrics.clone(),
    )));
    registry.borrow_mut().register(1, task);
    let probe = engine.add_actor(Box::new(NullActor));
    registry.borrow_mut().register(0, probe);
    engine.schedule(
        0,
        task,
        Msg::Data(Batch { from_task: 0, tuples: 7, bytes: 700, chunks: vec![], hist: None }),
    );
    engine.run_to_quiescence();
    let t = engine.actor_as::<OperatorTask>(task).unwrap();
    // both chain stages logged the batch
    assert_eq!(metrics.borrow().total(crate::metrics::Class::ConsumerTuples), 14);
    assert_eq!(t.batches_processed(), 1);
}

struct NullActor;
impl crate::sim::Actor<Msg> for NullActor {
    fn on_event(&mut self, _m: Msg, _c: &mut Ctx<'_, Msg>) {}
}
