//! OperatorTask tests: queueing, credits, backpressure, chaining, ticks.

use std::cell::RefCell;
use std::rc::Rc;

use super::*;
use crate::metrics::MetricsHub;
use crate::ops::{CountOp, OpOutput, Operator};
use crate::sim::{ActorId, Engine};

/// Upstream stub: sends N batches as fast as credits allow; records credit
/// returns.
struct Feeder {
    my_task: usize,
    target_task: usize,
    to_send: u64,
    tuples_per_batch: u64,
    ledger: CreditLedger,
    registry: SharedRegistry,
    credits_seen: Rc<RefCell<u64>>,
}

impl crate::sim::Actor<Msg> for Feeder {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.pump(ctx);
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        if let Msg::Credit { to_upstream_task, .. } = msg {
            *self.credits_seen.borrow_mut() += 1;
            self.ledger.refund(to_upstream_task);
            self.pump(ctx);
        }
    }
}

impl Feeder {
    fn pump(&mut self, ctx: &mut Ctx<'_, Msg>) {
        while self.to_send > 0 && self.ledger.has(self.target_task) {
            self.ledger.spend(self.target_task);
            self.to_send -= 1;
            let actor = self.registry.borrow().actor_of(self.target_task);
            ctx.send(
                actor,
                Msg::Data(Batch {
                    from_task: self.my_task,
                    tuples: self.tuples_per_batch,
                    chunks: crate::proto::ChunkList::Empty,
                    hist: None,
                    inc: 0,
                }),
            );
        }
    }
}

/// Slow terminal operator with a fixed per-batch cost.
struct SlowOp {
    per_batch: Time,
    seen: u64,
}

impl Operator for SlowOp {
    fn name(&self) -> &'static str {
        "slow"
    }
    fn cost(&self, _b: &Batch, _c: &CostModel) -> Time {
        self.per_batch
    }
    fn apply(&mut self, b: Batch, _f: usize, out: &mut OpOutput) -> Result<(), anyhow::Error> {
        self.seen += 1;
        out.tuples_logged += b.tuples;
        Ok(())
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

struct Rig {
    engine: Engine<Msg>,
    task: ActorId,
    metrics: SharedMetrics,
    credits_seen: Rc<RefCell<u64>>,
}

fn rig(n_batches: u64, queue_cap: usize, per_batch_ns: Time) -> Rig {
    let mut engine = Engine::new(1);
    let metrics = MetricsHub::shared();
    let registry = TaskRegistry::shared();
    let task = engine.add_actor(Box::new(OperatorTask::new(
        TaskParams {
            task_idx: 1,
            queue_cap,
            downstream: vec![],
            upstream: vec![0],
            tick_ns: crate::sim::SECOND,
            cost: CostModel::default(),
            checkpoint: None,
        },
        vec![Box::new(SlowOp { per_batch: per_batch_ns, seen: 0 })],
        registry.clone(),
        metrics.clone(),
    )));
    registry.borrow_mut().register(1, task);
    let credits_seen = Rc::new(RefCell::new(0u64));
    let feeder = engine.add_actor(Box::new(Feeder {
        my_task: 0,
        target_task: 1,
        to_send: n_batches,
        tuples_per_batch: 10,
        ledger: CreditLedger::new(&[1], queue_cap),
        registry: registry.clone(),
        credits_seen: credits_seen.clone(),
    }));
    registry.borrow_mut().register(0, feeder);
    Rig { engine, task, metrics, credits_seen }
}

#[test]
fn processes_all_batches_and_returns_credits() {
    let mut r = rig(20, 4, 1000);
    r.engine.run_to_quiescence();
    let t = r.engine.actor_as::<OperatorTask>(r.task).unwrap();
    assert_eq!(t.batches_processed(), 20);
    assert_eq!(*r.credits_seen.borrow(), 20);
    assert_eq!(
        r.metrics.borrow().total(crate::metrics::Class::ConsumerTuples),
        200
    );
}

#[test]
fn queue_depth_bounded_by_credits() {
    let mut r = rig(100, 3, 10_000);
    r.engine.run_to_quiescence();
    let t = r.engine.actor_as::<OperatorTask>(r.task).unwrap();
    assert_eq!(t.batches_processed(), 100);
    assert!(t.inbox_peak() <= 3, "credits cap the inbox: {}", t.inbox_peak());
}

#[test]
fn serial_processing_takes_cost_times_batches() {
    let mut r = rig(10, 2, 50_000);
    r.engine.run_to_quiescence();
    // 10 batches x 50us each, serially
    assert!(r.engine.now() >= 500_000, "serial task time: {}", r.engine.now());
}

#[test]
fn credit_ledger_protocol() {
    let mut l = CreditLedger::new(&[5, 6], 2);
    assert!(l.has(5));
    l.spend(5);
    l.spend(5);
    assert!(!l.has(5));
    assert!(l.has(6), "targets are independent");
    l.refund(5);
    assert!(l.has(5));
}

#[test]
#[should_panic(expected = "credit overflow")]
fn over_refund_is_a_bug() {
    let mut l = CreditLedger::new(&[1], 1);
    l.refund(1);
}

#[test]
#[should_panic(expected = "spending a credit")]
fn overspend_is_a_bug() {
    let mut l = CreditLedger::new(&[1], 1);
    l.spend(1);
    l.spend(1);
}

#[test]
fn registry_rejects_double_registration() {
    let reg = TaskRegistry::shared();
    reg.borrow_mut().register(0, ActorId(1));
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        reg.borrow_mut().register(0, ActorId(2));
    }));
    assert!(result.is_err());
}

#[test]
fn chained_operators_share_one_task() {
    // Chain: count -> count. Both see the batch; cost adds up.
    let mut engine = Engine::new(1);
    let metrics = MetricsHub::shared();
    let registry = TaskRegistry::shared();
    let task = engine.add_actor(Box::new(OperatorTask::new(
        TaskParams {
            task_idx: 1,
            queue_cap: 4,
            downstream: vec![],
            upstream: vec![0],
            tick_ns: crate::sim::SECOND,
            cost: CostModel::default(),
            checkpoint: None,
        },
        vec![Box::new(CountOp::default()), Box::new(CountOp::default())],
        registry.clone(),
        metrics.clone(),
    )));
    registry.borrow_mut().register(1, task);
    let probe = engine.add_actor(Box::new(NullActor));
    registry.borrow_mut().register(0, probe);
    engine.schedule(
        0,
        task,
        Msg::Data(Batch {
            from_task: 0,
            tuples: 7,
            chunks: crate::proto::ChunkList::Empty,
            hist: None,
            inc: 0,
        }),
    );
    engine.run_to_quiescence();
    let t = engine.actor_as::<OperatorTask>(task).unwrap();
    // both chain stages logged the batch
    assert_eq!(metrics.borrow().total(crate::metrics::Class::ConsumerTuples), 14);
    assert_eq!(t.batches_processed(), 1);
}

struct NullActor;
impl crate::sim::Actor<Msg> for NullActor {
    fn on_event(&mut self, _m: Msg, _c: &mut Ctx<'_, Msg>) {}
}

// ---------------------------------------------------------------------------
// Checkpoint barriers + fault/restore
// ---------------------------------------------------------------------------

use crate::checkpoint::{CheckpointControl, SharedCheckpoint};
use crate::ops::OpState;
use crate::sim::MICROS;

/// Records every message it receives (stands in for the coordinator).
struct Catcher {
    seen: Rc<RefCell<Vec<Msg>>>,
}

impl crate::sim::Actor<Msg> for Catcher {
    fn on_event(&mut self, m: Msg, _c: &mut Ctx<'_, Msg>) {
        self.seen.borrow_mut().push(m);
    }
}

struct CkptRig {
    engine: Engine<Msg>,
    task: ActorId,
    control: SharedCheckpoint,
    coord_seen: Rc<RefCell<Vec<Msg>>>,
}

/// One count task with two upstream channels (0 and 1) and a scripted
/// coordinator stand-in.
fn ckpt_rig() -> CkptRig {
    let mut engine = Engine::new(1);
    let metrics = MetricsHub::shared();
    let registry = TaskRegistry::shared();
    let control = CheckpointControl::shared();
    let task = engine.add_actor(Box::new(OperatorTask::new(
        TaskParams {
            task_idx: 2,
            queue_cap: 8,
            downstream: vec![],
            upstream: vec![0, 1],
            tick_ns: crate::sim::SECOND,
            cost: CostModel::default(),
            checkpoint: Some(control.clone()),
        },
        vec![Box::new(CountOp::default())],
        registry.clone(),
        metrics,
    )));
    registry.borrow_mut().register(2, task);
    for idx in [0usize, 1] {
        let probe = engine.add_actor(Box::new(NullActor));
        registry.borrow_mut().register(idx, probe);
    }
    let coord_seen = Rc::new(RefCell::new(Vec::new()));
    let coordinator = engine.add_actor(Box::new(Catcher { seen: coord_seen.clone() }));
    control.borrow_mut().coordinator = Some(coordinator);
    CkptRig { engine, task, control, coord_seen }
}

fn data(from_task: usize, tuples: u64, inc: u64) -> Msg {
    Msg::Data(Batch {
        from_task,
        tuples,
        chunks: crate::proto::ChunkList::Empty,
        hist: None,
        inc,
    })
}

#[test]
fn barrier_aligns_over_both_upstream_channels() {
    let mut r = ckpt_rig();
    r.control.borrow_mut().begin(1);
    // Channel 0: one pre-barrier batch, the barrier, one post-barrier batch
    // (must be buffered until channel 1's barrier arrives). Channel 1: a
    // pre-barrier batch, then its barrier.
    r.engine.schedule(0, r.task, data(0, 5, 0));
    r.engine.schedule(10 * MICROS, r.task, Msg::Barrier { epoch: 1, from_task: 0 });
    r.engine.schedule(20 * MICROS, r.task, data(0, 7, 0));
    r.engine.schedule(30 * MICROS, r.task, data(1, 9, 0));
    r.engine.schedule(40 * MICROS, r.task, Msg::Barrier { epoch: 1, from_task: 1 });
    r.engine.run_until(SECOND);
    // The snapshot reflects exactly the pre-barrier batches (5 + 9), not
    // the buffered post-barrier one.
    {
        let c = r.control.borrow();
        assert_eq!(c.pending_epoch(), Some(1));
        assert_eq!(c.align_spans, 1, "one task aligned once");
        assert!(c.align_ns_max >= 30 * MICROS, "aligned across the barrier gap");
    }
    let snap = {
        let mut c = r.control.borrow_mut();
        c.complete(1);
        c.task_snapshot(r.task).expect("task snapshotted")
    };
    assert_eq!(snap.ops, vec![OpState::Count { total: 14 }]);
    // The coordinator got exactly one ack, for epoch 1.
    let acks: Vec<u64> = r
        .coord_seen
        .borrow()
        .iter()
        .filter_map(|m| match m {
            Msg::BarrierAck { epoch, .. } => Some(*epoch),
            _ => None,
        })
        .collect();
    assert_eq!(acks, vec![1]);
    // The buffered post-barrier batch was processed after the snapshot.
    let t = r.engine.actor_as::<OperatorTask>(r.task).unwrap();
    assert_eq!(t.batches_processed(), 3);
    assert_eq!(t.op_as::<CountOp>(0).unwrap().total, 21);
}

#[test]
fn stale_barriers_are_ignored() {
    let mut r = ckpt_rig();
    r.control.borrow_mut().begin(3);
    // Epoch 2 is below the floor after a restore carrying epoch_floor=2.
    r.engine.schedule(0, r.task, Msg::Restore { inc: 1, epoch_floor: 2 });
    r.engine.schedule(10 * MICROS, r.task, Msg::Barrier { epoch: 2, from_task: 0 });
    r.engine.schedule(20 * MICROS, r.task, Msg::Barrier { epoch: 2, from_task: 1 });
    // Epoch 3 is live and must still align.
    r.engine.schedule(30 * MICROS, r.task, Msg::Barrier { epoch: 3, from_task: 0 });
    r.engine.schedule(40 * MICROS, r.task, Msg::Barrier { epoch: 3, from_task: 1 });
    r.engine.run_until(SECOND);
    let acks: Vec<u64> = r
        .coord_seen
        .borrow()
        .iter()
        .filter_map(|m| match m {
            Msg::BarrierAck { epoch, .. } => Some(*epoch),
            _ => None,
        })
        .collect();
    assert_eq!(acks, vec![3], "only the live epoch aligns");
}

#[test]
fn fault_wipes_state_and_restore_rolls_back_to_the_snapshot() {
    let mut r = ckpt_rig();
    r.control.borrow_mut().begin(1);
    // Pre-barrier work: 5 + 9 tuples land in the epoch-1 snapshot.
    r.engine.schedule(0, r.task, data(0, 5, 0));
    r.engine.schedule(0, r.task, data(1, 9, 0));
    r.engine.schedule(10 * MICROS, r.task, Msg::Barrier { epoch: 1, from_task: 0 });
    r.engine.schedule(10 * MICROS, r.task, Msg::Barrier { epoch: 1, from_task: 1 });
    // Post-checkpoint work that the fault must lose.
    r.engine.schedule(30 * MICROS, r.task, data(0, 100, 0));
    r.engine.run_until(SECOND);
    r.control.borrow_mut().complete(1);
    {
        let t = r.engine.actor_as::<OperatorTask>(r.task).unwrap();
        assert_eq!(t.op_as::<CountOp>(0).unwrap().total, 114);
    }
    let now = r.engine.now();
    r.engine.schedule(now, r.task, Msg::Fault { kind: crate::config::FaultKind::Worker });
    // While dead: input is ignored entirely.
    r.engine.schedule(now + 10 * MICROS, r.task, data(1, 50, 0));
    r.engine.schedule(now + 20 * MICROS, r.task, Msg::Restore { inc: 1, epoch_floor: 1 });
    // After the restore: old-incarnation batches are dropped, new ones run.
    r.engine.schedule(now + 30 * MICROS, r.task, data(0, 40, 0)); // stale inc
    r.engine.schedule(now + 40 * MICROS, r.task, data(1, 6, 1)); // current inc
    r.engine.run_until(2 * SECOND);
    let failure_reported = r
        .coord_seen
        .borrow()
        .iter()
        .any(|m| matches!(m, Msg::FailureDetected { .. }));
    assert!(failure_reported, "the failure detector alerted the coordinator");
    let restored_acked =
        r.coord_seen.borrow().iter().any(|m| matches!(m, Msg::RestoreAck { .. }));
    assert!(restored_acked);
    let t = r.engine.actor_as::<OperatorTask>(r.task).unwrap();
    // 14 from the snapshot + 6 post-restore; the 100 was rolled back, the
    // 50 died with the process, the stale 40 was dropped.
    assert_eq!(t.op_as::<CountOp>(0).unwrap().total, 20);
}
