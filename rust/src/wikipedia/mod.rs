//! The text corpus for the Wikipedia benchmarks (paper §V-B).
//!
//! The paper's producers "read and ingest Wikipedia files in chunks having
//! records of 2 KiB" (§V-A). A Wikipedia dump is not available offline, so
//! a bundled public-domain/bespoke encyclopedic corpus is tiled to the size
//! an experiment needs (DESIGN.md §2, substitution 4) — what matters to the
//! benchmark is that records are realistic English text with a Zipf-ish
//! word distribution, because the tokenizer and the keyed state are the
//! CPU bottleneck the paper measures.

#[cfg(test)]
mod tests;

use std::rc::Rc;

/// Built-in corpus: encyclopedic prose, ASCII, public-domain phrasing.
pub const CORPUS: &str = concat!(
    "Stream processing is a computer programming paradigm that treats ",
    "sequences of events as the primary input and output of computation. ",
    "A streaming architecture ingests records from producers, stores them ",
    "in partitioned logs managed by brokers, and serves them to consumers ",
    "that subscribe to topics. The broker decouples producers from ",
    "consumers so that availability and durability of data streams are ",
    "managed separately from the processing engines. ",
    "Apache Flink is an open source framework for stateful computations ",
    "over unbounded and bounded data streams. Flink deploys source, sink ",
    "and transformation operators on worker slots and manages consistent ",
    "state through periodic checkpoints and watermarks. The source ",
    "operator pulls data from the assigned topic partitions of the ",
    "message broker and makes records available to pipelined tasks ",
    "through queues. Backpressure occurs when slow operators fill the ",
    "queues faster than downstream tasks can drain them. ",
    "A log structured storage system appends records to segments of fixed ",
    "size and retains them until every registered consumer has passed the ",
    "retention watermark. Replication copies each segment to a backup ",
    "broker on a separate node so that a crash does not lose acknowledged ",
    "data. The dispatcher thread of the broker polls the network and ",
    "hands each remote procedure call to a pool of worker cores that ",
    "perform the actual reads and writes. ",
    "The university cluster comprises multicore nodes with two processors ",
    "of sixty four cores each and two hundred fifty six gigabytes of ",
    "memory, interconnected through a high performance fabric of one ",
    "hundred gigabits per second. Jobs are scheduled with a batch system ",
    "and executed inside containers for reproducibility. ",
    "In the year 1881 the observatory recorded 365 nights of data and the ",
    "archive grew by 12 gigabytes, a volume considered enormous at the ",
    "time. Modern accelerators log tens of billions of events per day and ",
    "the logging service processes terabytes of measurements for physics ",
    "analysis, monitoring and alarms. ",
    "Shared memory allows two processes on the same node to exchange data ",
    "through pointers to common buffers instead of copying bytes over a ",
    "socket. An object store partitions its memory into objects that are ",
    "created, sealed, mapped and released; reference counts ensure that a ",
    "buffer is reused only after every reader has finished. Locality of ",
    "reference reduces latency because the consumer reads the record from ",
    "the cache of the producing core rather than across the network. ",
);

/// A reader that serves the corpus as fixed-size records, tiling the text
/// end-to-end (records never span a tile boundary mid-token in a way that
/// matters: the boundary just ends a token, like any record boundary).
#[derive(Debug)]
pub struct CorpusReader {
    data: Rc<Vec<u8>>,
    pos: usize,
    record_size: usize,
    /// Total records this reader will serve (the paper's producers push a
    /// bounded volume — about 2 GiB — then stop).
    remaining: u64,
}

impl CorpusReader {
    /// Reader over the built-in corpus serving `total_records` records of
    /// `record_size` bytes.
    pub fn new(record_size: usize, total_records: u64) -> Self {
        assert!(record_size > 0);
        Self {
            data: Rc::new(CORPUS.as_bytes().to_vec()),
            pos: 0,
            record_size,
            remaining: total_records,
        }
    }

    /// Reader over caller-provided text (tests, real files).
    pub fn from_text(text: &str, record_size: usize, total_records: u64) -> Self {
        assert!(record_size > 0);
        assert!(!text.is_empty());
        Self {
            data: Rc::new(text.as_bytes().to_vec()),
            pos: 0,
            record_size,
            remaining: total_records,
        }
    }

    /// Records left to serve.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// Fill `out` (a whole number of records) with corpus text; returns the
    /// number of records written (0 when exhausted).
    pub fn fill_records(&mut self, out: &mut [u8]) -> usize {
        debug_assert_eq!(out.len() % self.record_size, 0);
        let want = (out.len() / self.record_size).min(self.remaining as usize);
        for r in 0..want {
            let rec = &mut out[r * self.record_size..(r + 1) * self.record_size];
            let mut filled = 0;
            while filled < rec.len() {
                let take = (rec.len() - filled).min(self.data.len() - self.pos);
                rec[filled..filled + take]
                    .copy_from_slice(&self.data[self.pos..self.pos + take]);
                filled += take;
                self.pos = (self.pos + take) % self.data.len();
            }
        }
        self.remaining -= want as u64;
        want
    }

    /// Exact token count of `data` under the shared token semantics
    /// (maximal `[a-zA-Z0-9]` runs; boundaries end tokens). Used by
    /// integration tests to validate real-plane word counts end to end.
    pub fn count_tokens(data: &[u8]) -> u64 {
        let mut count = 0;
        let mut in_word = false;
        for &b in data {
            let tok = b.is_ascii_alphanumeric();
            if in_word && !tok {
                count += 1;
            }
            in_word = tok;
        }
        if in_word {
            count += 1;
        }
        count
    }
}
