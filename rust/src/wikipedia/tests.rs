//! Corpus reader tests.

use super::*;

#[test]
fn corpus_is_nontrivial_ascii_text() {
    assert!(CORPUS.len() > 2000);
    assert!(CORPUS.is_ascii());
    assert!(CORPUS.contains("broker"));
}

#[test]
fn fill_serves_whole_records() {
    let mut r = CorpusReader::new(128, 10);
    let mut buf = vec![0u8; 4 * 128];
    assert_eq!(r.fill_records(&mut buf), 4);
    assert_eq!(r.remaining(), 6);
    assert!(buf.iter().all(|&b| b != 0), "records fully filled with text");
}

#[test]
fn budget_exhaustion_stops_the_reader() {
    let mut r = CorpusReader::new(64, 3);
    let mut buf = vec![0u8; 5 * 64];
    assert_eq!(r.fill_records(&mut buf), 3);
    assert_eq!(r.fill_records(&mut buf), 0);
    assert_eq!(r.remaining(), 0);
}

#[test]
fn text_tiles_across_the_corpus_boundary() {
    let mut r = CorpusReader::from_text("abc ", 8, 4);
    let mut buf = vec![0u8; 8];
    r.fill_records(&mut buf);
    assert_eq!(&buf, b"abc abc ");
}

#[test]
fn records_are_deterministic_sequence() {
    let mut a = CorpusReader::new(256, 100);
    let mut b = CorpusReader::new(256, 100);
    let (mut ba, mut bb) = (vec![0u8; 256 * 3], vec![0u8; 256 * 3]);
    a.fill_records(&mut ba);
    b.fill_records(&mut bb);
    assert_eq!(ba, bb);
}

mod tokens {
    use super::*;

    #[test]
    fn counts_simple_words() {
        assert_eq!(CorpusReader::count_tokens(b"hello world"), 2);
        assert_eq!(CorpusReader::count_tokens(b"  a  b  "), 2);
        assert_eq!(CorpusReader::count_tokens(b""), 0);
        assert_eq!(CorpusReader::count_tokens(b"..."), 0);
    }

    #[test]
    fn digits_and_trailing_token() {
        assert_eq!(CorpusReader::count_tokens(b"year 1881 end"), 3);
        assert_eq!(CorpusReader::count_tokens(b"endword"), 1);
    }

    #[test]
    fn corpus_token_density_is_realistic() {
        // ~5-6 chars per word + space: a 2 KiB record holds roughly
        // 250-400 tokens. The sim-plane default (cost.tokens_per_record)
        // must be in that ballpark.
        let mut r = CorpusReader::new(2048, 1);
        let mut buf = vec![0u8; 2048];
        r.fill_records(&mut buf);
        let tokens = CorpusReader::count_tokens(&buf);
        assert!((250..=420).contains(&tokens), "tokens in 2 KiB: {tokens}");
    }
}
