//! Sharding: partitions spread across N brokers, replica sets, rebalance.
//!
//! Everything before this subsystem funnelled through one broker actor
//! (plus one optional backup). Serving real traffic means partitions
//! **sharded** across many brokers with replicated, rebalance-able
//! ownership — the topology the Uber real-time-infrastructure paper
//! describes, operated through the coordinator/broker split of Isah &
//! Zulkernine's ingestion framework. This module is that control plane:
//!
//! * [`ShardTable`] — the versioned partition → replica-set assignment
//!   table. Range-based: each broker owns a contiguous run of `Ns /
//!   broker_count` partitions (seed-rotated so broker 0 is not special),
//!   and partition `p`'s replica set is the `replication_factor` brokers
//!   starting at its primary. Pure function of `(Ns, broker_count,
//!   replication_factor, seed)` — same inputs, same table, on every node.
//! * [`ShardState`] / [`SharedShard`] — the shared blackboard (same
//!   `Rc<RefCell>` idiom as the plasma store) holding the **published**
//!   table plus the broker actor roster. Only the coordinator writes it.
//! * [`ShardClient`] — the cached routing view producers and sources hold:
//!   a table snapshot plus its epoch. Routing decisions use the cache;
//!   [`ShardClient::refresh`] re-snapshots after a
//!   [`crate::proto::RpcReply::WrongShard`] reply or a
//!   [`crate::proto::Msg::ShardEpoch`] notification.
//! * [`BrokerShard`] — the broker-side view: this broker's index, the
//!   partitions it currently serves as primary (mutated by freeze /
//!   promote), and each partition's replica peers.
//! * [`ShardCoordinator`] — the actor that owns the table's lifecycle and
//!   drives live rebalancing.
//!
//! ## The assignment-epoch contract
//!
//! The table carries a monotonically increasing `epoch`. The rules that
//! make cached routing safe:
//!
//! 1. **Clients route on a cached epoch.** A producer or source resolves
//!    `partition → broker` from its snapshot and never blocks on the
//!    coordinator.
//! 2. **Brokers are the authority.** Every data-path request against a
//!    partition the broker does not currently serve as primary is refused
//!    with `WrongShard { epoch }` — never silently served, never panicked.
//!    A quorum-committed append is still acked even if the partition froze
//!    while the acks were in flight (the data is on the replicas; the
//!    hand-off waits for exactly those acks).
//! 3. **Stale clients converge.** On `WrongShard` (or `ShardEpoch`) the
//!    client refreshes its snapshot and retries. Because the coordinator
//!    always publishes the new table after a hand-off, the retry loop
//!    terminates; retries are therefore *unbounded* (counted, backed off)
//!    rather than budgeted like genuine rejections.
//! 4. **Hand-off is drain → checkpoint cursors → reassign → resume.**
//!    Freeze stops the old primary and drains its in-flight replication;
//!    push sources checkpoint their cursors through `PushUnsubscribe`
//!    (the same `SourceSnapshot` cursor primitive checkpointing uses);
//!    promote turns the standing replica into the new primary; publishing
//!    the table resumes routing. Replica logs apply appends at
//!    **primary-assigned offsets**, so the new primary's log is
//!    byte-identical to the old one's and cursors carry over unchanged —
//!    zero loss, zero duplication.

use std::cell::RefCell;
use std::collections::HashSet;
use std::rc::Rc;

use crate::config::CostModel;
use crate::net::{NodeId, SharedNetwork};
use crate::proto::{Msg, PartitionId, RpcKind, RpcReply, RpcRequest};
use crate::sim::{Actor, ActorId, Ctx, Time};

// ---------------------------------------------------------------------------
// The assignment table
// ---------------------------------------------------------------------------

/// The versioned partition → replica-set assignment table.
///
/// `replicas[p][0]` is partition `p`'s primary; the rest of the row are
/// its standing replicas. See the module docs for the epoch contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardTable {
    /// Monotone version; bumped by every reassignment.
    pub epoch: u64,
    /// Broker count the table spans.
    brokers: usize,
    /// Replica-set size (`replication_factor`).
    replication: usize,
    /// Per-partition replica sets, primary first.
    replicas: Vec<Vec<usize>>,
}

impl ShardTable {
    /// Build the initial table: contiguous ranges of `partitions /
    /// brokers` partitions, the range→broker mapping rotated by the seed,
    /// replica `j` of a partition on `(primary + j) % brokers`. Pure —
    /// every node building with the same inputs gets the same table.
    pub fn build(partitions: usize, brokers: usize, replication: usize, seed: u64) -> Self {
        assert!(brokers > 0 && partitions > 0, "shard table needs brokers and partitions");
        assert!(
            partitions % brokers == 0,
            "Ns={partitions} must divide across broker_count={brokers}"
        );
        assert!(
            (1..=brokers).contains(&replication),
            "replication_factor={replication} must be in 1..=broker_count={brokers}"
        );
        let span = partitions / brokers;
        let offset = (seed % brokers as u64) as usize;
        let replicas = (0..partitions)
            .map(|p| {
                let primary = (p / span + offset) % brokers;
                (0..replication).map(|j| (primary + j) % brokers).collect()
            })
            .collect();
        ShardTable { epoch: 0, brokers, replication, replicas }
    }

    pub fn partitions(&self) -> usize {
        self.replicas.len()
    }

    pub fn brokers(&self) -> usize {
        self.brokers
    }

    pub fn replication(&self) -> usize {
        self.replication
    }

    /// The broker currently serving `p` as primary.
    pub fn primary(&self, p: PartitionId) -> usize {
        self.replicas[p.0][0]
    }

    /// `p`'s full replica set, primary first.
    pub fn replica_set(&self, p: PartitionId) -> &[usize] {
        &self.replicas[p.0]
    }

    /// Does broker `b` hold a replica (primary or standing) of `p`?
    pub fn hosts(&self, p: PartitionId, b: usize) -> bool {
        self.replicas[p.0].contains(&b)
    }

    /// Acks (including the primary's own append) that commit a write:
    /// a majority of the replica set.
    pub fn quorum(&self) -> usize {
        self.replication / 2 + 1
    }

    /// The partitions broker `b` currently serves as primary, ascending.
    pub fn primaries_of(&self, b: usize) -> Vec<PartitionId> {
        (0..self.replicas.len())
            .map(PartitionId)
            .filter(|&p| self.primary(p) == b)
            .collect()
    }

    /// The rebalanced table the coordinator hands off to: every replica
    /// set rotated left, so each partition's standing first replica
    /// becomes its primary. Requires `replication_factor >= 2` (with one
    /// replica there is nothing to promote).
    pub fn rotated(&self) -> ShardTable {
        assert!(self.replication >= 2, "rotation promotes the standing replica");
        let replicas = self
            .replicas
            .iter()
            .map(|set| {
                let mut s = set.clone();
                s.rotate_left(1);
                s
            })
            .collect();
        ShardTable {
            epoch: self.epoch + 1,
            brokers: self.brokers,
            replication: self.replication,
            replicas,
        }
    }

    /// Grow the fleet by one broker with minimal movement: the new broker
    /// takes `ceil(P / (N+1))` partitions, stolen one at a time from
    /// whichever broker is most loaded; everything else stays put. The
    /// stability property the tests pin: adding a broker never moves more
    /// than `ceil(P / N_new)` primaries.
    pub fn grown(&self) -> ShardTable {
        let new_brokers = self.brokers + 1;
        let p_total = self.replicas.len();
        let target = p_total.div_ceil(new_brokers);
        let mut primaries: Vec<usize> = (0..p_total).map(|p| self.replicas[p][0]).collect();
        let mut load = vec![0usize; new_brokers];
        for &b in &primaries {
            load[b] += 1;
        }
        for _ in 0..target {
            let donor = (0..self.brokers).max_by_key(|&b| load[b]).expect("brokers > 0");
            if load[donor] == 0 {
                break;
            }
            let victim = (0..p_total)
                .rev()
                .find(|&p| primaries[p] == donor)
                .expect("donor has load");
            primaries[victim] = self.brokers;
            load[donor] -= 1;
            load[self.brokers] += 1;
        }
        let replicas = primaries
            .iter()
            .map(|&primary| (0..self.replication).map(|j| (primary + j) % new_brokers).collect())
            .collect();
        ShardTable {
            epoch: self.epoch + 1,
            brokers: new_brokers,
            replication: self.replication,
            replicas,
        }
    }

    /// How many partitions changed primary between two tables.
    pub fn moved_primaries(&self, other: &ShardTable) -> usize {
        assert_eq!(self.replicas.len(), other.replicas.len(), "comparable tables");
        (0..self.replicas.len())
            .filter(|&p| self.replicas[p][0] != other.replicas[p][0])
            .count()
    }
}

// ---------------------------------------------------------------------------
// Shared state + client cache
// ---------------------------------------------------------------------------

/// The published shard view: the current table plus the broker roster.
/// Written only by the [`ShardCoordinator`] (after a complete hand-off);
/// read by every [`ShardClient`] refresh.
#[derive(Debug)]
pub struct ShardState {
    pub table: ShardTable,
    /// Broker actors by table index.
    pub brokers: Vec<(ActorId, NodeId)>,
}

/// Shared handle (same idiom as the plasma store blackboard).
pub type SharedShard = Rc<RefCell<ShardState>>;

impl ShardState {
    pub fn shared(table: ShardTable) -> SharedShard {
        Rc::new(RefCell::new(ShardState { table, brokers: Vec::new() }))
    }
}

/// A client's cached routing view (producers and sources hold one each).
/// Routing never touches the shared state; [`ShardClient::refresh`]
/// re-snapshots after a staleness signal.
#[derive(Debug, Clone)]
pub struct ShardClient {
    shard: SharedShard,
    table: ShardTable,
    brokers: Vec<(ActorId, NodeId)>,
}

impl ShardClient {
    pub fn new(shard: &SharedShard) -> Self {
        let s = shard.borrow();
        ShardClient { shard: shard.clone(), table: s.table.clone(), brokers: s.brokers.clone() }
    }

    /// The cached assignment epoch.
    pub fn epoch(&self) -> u64 {
        self.table.epoch
    }

    /// Resolve `p`'s primary broker under the cached table.
    pub fn broker_for(&self, p: PartitionId) -> (ActorId, NodeId) {
        self.brokers[self.table.primary(p)]
    }

    /// The cached table (for grouping partitions by destination).
    pub fn table(&self) -> &ShardTable {
        &self.table
    }

    /// Re-snapshot the published view; `true` if the epoch advanced.
    pub fn refresh(&mut self) -> bool {
        let s = self.shard.borrow();
        let advanced = s.table.epoch > self.table.epoch;
        if advanced {
            self.table = s.table.clone();
            self.brokers = s.brokers.clone();
        }
        advanced
    }
}

// ---------------------------------------------------------------------------
// Broker-side view
// ---------------------------------------------------------------------------

/// What a shard broker knows about its place in the table: its index, the
/// partitions it currently serves as primary (freeze removes, promote
/// adds), and each partition's replica peers for quorum fan-out.
#[derive(Debug)]
pub struct BrokerShard {
    /// This broker's index in the table.
    pub index: usize,
    /// The assignment epoch this broker last heard (freeze/promote carry
    /// it forward; `WrongShard` replies report it).
    pub epoch: u64,
    /// Partitions currently served as primary.
    pub primaries: HashSet<PartitionId>,
    /// The build-time table (replica-set membership is stable across
    /// rotations, so peers stay valid across hand-offs).
    pub table: ShardTable,
    /// Broker roster by table index (includes self at `index`).
    pub peers: Vec<(ActorId, NodeId)>,
}

impl BrokerShard {
    pub fn new(index: usize, table: ShardTable, peers: Vec<(ActorId, NodeId)>) -> Self {
        let primaries = table.primaries_of(index).into_iter().collect();
        BrokerShard { index, epoch: table.epoch, primaries, table, peers }
    }

    /// Is this broker the current primary for `p`?
    pub fn is_primary(&self, p: PartitionId) -> bool {
        self.primaries.contains(&p)
    }

    /// The non-self replica peers of `p`, for replication fan-out.
    pub fn replica_peers(&self, p: PartitionId) -> Vec<(ActorId, NodeId)> {
        self.table
            .replica_set(p)
            .iter()
            .filter(|&&b| b != self.index)
            .map(|&b| self.peers[b])
            .collect()
    }

    /// Peer acks needed before a write commits (the primary's own append
    /// is the first quorum vote).
    pub fn needed_peer_acks(&self) -> usize {
        self.table.quorum() - 1
    }
}

// ---------------------------------------------------------------------------
// The coordinator actor
// ---------------------------------------------------------------------------

/// Static coordinator wiring.
#[derive(Debug, Clone)]
pub struct ShardCoordinatorParams {
    /// Node the coordinator runs on (the colocated worker node).
    pub node: NodeId,
    /// Force one live rebalance (table rotation) at this virtual time;
    /// 0 = own the table but never move it.
    pub rebalance_at: Time,
    /// Source actors to notify when a new table publishes.
    pub sources: Vec<ActorId>,
    pub cost: CostModel,
}

/// End-of-run rebalance accounting (exported as gauges by the launcher).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Completed hand-offs.
    pub rebalances: u64,
    /// Primaries moved across all hand-offs.
    pub partitions_moved: u64,
    /// Freeze-trigger → table-publish span of the last hand-off (ns).
    pub handoff_ns: u64,
}

/// The hand-off state machine: freeze the losing primaries, wait for
/// their drains, promote the gaining replicas, publish.
enum Handoff {
    Idle,
    Freezing { table: ShardTable, acks: usize, expect: usize, started: Time },
    Promoting { table: ShardTable, acks: usize, expect: usize, started: Time },
}

/// The actor that owns the assignment table's lifecycle: it publishes the
/// initial table (built by the launcher), and on `rebalance_at` drives
/// the live hand-off protocol — drain (freeze) → reassign (promote) →
/// resume (publish + notify sources). Producers need no notification:
/// their next `WrongShard` retry refreshes against the published table.
pub struct ShardCoordinator {
    params: ShardCoordinatorParams,
    shard: SharedShard,
    net: SharedNetwork,
    handoff: Handoff,
    next_rpc: u64,
    stats: ShardStats,
}

impl ShardCoordinator {
    pub fn new(params: ShardCoordinatorParams, shard: SharedShard, net: SharedNetwork) -> Self {
        Self { params, shard, net, handoff: Handoff::Idle, next_rpc: 0, stats: ShardStats::default() }
    }

    pub fn stats(&self) -> ShardStats {
        self.stats.clone()
    }

    fn rpc(&mut self, to: (ActorId, NodeId), kind: RpcKind, ctx: &mut Ctx<'_, Msg>) {
        let id = self.next_rpc;
        self.next_rpc += 1;
        let deliver = self.net.borrow_mut().send_control(ctx.now(), self.params.node, to.1);
        ctx.send_at(
            deliver,
            to.0,
            Msg::rpc(RpcRequest {
                id,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind,
            }),
        );
    }

    /// Start the hand-off: compute the rotated table and freeze every
    /// broker that loses a primary under it.
    fn begin_rebalance(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let (old, brokers) = {
            let s = self.shard.borrow();
            (s.table.clone(), s.brokers.clone())
        };
        let table = old.rotated();
        self.stats.partitions_moved += old.moved_primaries(&table) as u64;
        let mut expect = 0;
        for (b, &peer) in brokers.iter().enumerate() {
            let lost: Vec<PartitionId> = old
                .primaries_of(b)
                .into_iter()
                .filter(|&p| table.primary(p) != b)
                .collect();
            if !lost.is_empty() {
                self.rpc(peer, RpcKind::ShardFreeze { epoch: table.epoch, partitions: lost }, ctx);
                expect += 1;
            }
        }
        if expect == 0 {
            self.publish(table, ctx);
        } else {
            self.handoff = Handoff::Freezing { table, acks: 0, expect, started: ctx.now() };
        }
    }

    /// All drains complete: promote every broker that gains a primary.
    fn begin_promote(&mut self, table: ShardTable, started: Time, ctx: &mut Ctx<'_, Msg>) {
        let (old, brokers) = {
            let s = self.shard.borrow();
            (s.table.clone(), s.brokers.clone())
        };
        let mut expect = 0;
        for (b, &peer) in brokers.iter().enumerate() {
            let gained: Vec<PartitionId> = table
                .primaries_of(b)
                .into_iter()
                .filter(|&p| old.primary(p) != b)
                .collect();
            if !gained.is_empty() {
                self.rpc(
                    peer,
                    RpcKind::ShardPromote { epoch: table.epoch, partitions: gained },
                    ctx,
                );
                expect += 1;
            }
        }
        assert!(expect > 0, "a hand-off that froze primaries must promote them somewhere");
        self.handoff = Handoff::Promoting { table, acks: 0, expect, started };
    }

    /// Resume: publish the new table and nudge the sources (producers
    /// converge through WrongShard retries on their own).
    fn publish(&mut self, table: ShardTable, ctx: &mut Ctx<'_, Msg>) {
        let epoch = table.epoch;
        self.shard.borrow_mut().table = table;
        for &s in &self.params.sources {
            ctx.send_in(self.params.cost.notify_ns, s, Msg::ShardEpoch { epoch });
        }
        self.stats.rebalances += 1;
        self.handoff = Handoff::Idle;
    }

    fn on_reply(&mut self, reply: RpcReply, ctx: &mut Ctx<'_, Msg>) {
        match reply {
            RpcReply::FreezeAck { .. } => {
                let done = match &mut self.handoff {
                    Handoff::Freezing { acks, expect, .. } => {
                        *acks += 1;
                        *acks == *expect
                    }
                    _ => panic!("shard coordinator: freeze ack outside a freeze phase"),
                };
                if !done {
                    return;
                }
                let Handoff::Freezing { table, started, .. } =
                    std::mem::replace(&mut self.handoff, Handoff::Idle)
                else {
                    unreachable!()
                };
                self.begin_promote(table, started, ctx);
            }
            RpcReply::PromoteAck { .. } => {
                let done = match &mut self.handoff {
                    Handoff::Promoting { acks, expect, .. } => {
                        *acks += 1;
                        *acks == *expect
                    }
                    _ => panic!("shard coordinator: promote ack outside a promote phase"),
                };
                if !done {
                    return;
                }
                let Handoff::Promoting { table, started, .. } =
                    std::mem::replace(&mut self.handoff, Handoff::Idle)
                else {
                    unreachable!()
                };
                self.stats.handoff_ns = ctx.now() - started;
                self.publish(table, ctx);
            }
            RpcReply::Error { reason } => {
                panic!("shard coordinator: broker refused a hand-off step: {reason}")
            }
            other => panic!("shard coordinator: unexpected reply {other:?}"),
        }
    }
}

impl Actor<Msg> for ShardCoordinator {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.params.rebalance_at > 0 {
            ctx.send_self_in(self.params.rebalance_at, Msg::Timer(0));
        }
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Timer(_) => {
                assert!(
                    matches!(self.handoff, Handoff::Idle),
                    "rebalance trigger while a hand-off is in flight"
                );
                self.begin_rebalance(ctx);
            }
            Msg::Reply(env) => self.on_reply(env.reply, ctx),
            other => panic!("shard coordinator: unexpected {other:?}"),
        }
    }

    fn label(&self) -> String {
        "shard-coordinator".into()
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

// ---------------------------------------------------------------------------
// Table property tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::proptest::forall;

    #[test]
    fn build_is_deterministic_in_its_inputs() {
        forall(200, |rng| {
            let brokers = rng.range(1, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(1, brokers as u64) as usize;
            let seed = rng.next_u64();
            let a = ShardTable::build(partitions, brokers, replication, seed);
            let b = ShardTable::build(partitions, brokers, replication, seed);
            assert_eq!(a, b, "same inputs, same table");
        });
    }

    #[test]
    fn every_partition_has_a_distinct_full_replica_set() {
        forall(200, |rng| {
            let brokers = rng.range(1, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(1, brokers as u64) as usize;
            let t = ShardTable::build(partitions, brokers, replication, rng.next_u64());
            for p in (0..partitions).map(PartitionId) {
                let set = t.replica_set(p);
                assert_eq!(set.len(), replication);
                let distinct: HashSet<_> = set.iter().collect();
                assert_eq!(distinct.len(), replication, "replicas land on distinct brokers");
                assert!(t.hosts(p, t.primary(p)));
            }
        });
    }

    #[test]
    fn ranges_balance_exactly() {
        forall(200, |rng| {
            let brokers = rng.range(1, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let t = ShardTable::build(partitions, brokers, 1, rng.next_u64());
            for b in 0..brokers {
                assert_eq!(t.primaries_of(b).len(), partitions / brokers);
            }
        });
    }

    #[test]
    fn growing_the_fleet_moves_at_most_a_fair_share() {
        forall(300, |rng| {
            let brokers = rng.range(1, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(1, brokers as u64) as usize;
            let t = ShardTable::build(partitions, brokers, replication, rng.next_u64());
            let g = t.grown();
            assert_eq!(g.brokers(), brokers + 1);
            assert_eq!(g.epoch, t.epoch + 1);
            let moved = t.moved_primaries(&g);
            let bound = partitions.div_ceil(brokers + 1);
            assert!(
                moved <= bound,
                "grow moved {moved} primaries, bound ceil({partitions}/{}) = {bound}",
                brokers + 1
            );
            // Everything that moved landed on the new broker.
            assert_eq!(g.primaries_of(brokers).len(), moved);
        });
    }

    #[test]
    fn rotation_promotes_the_standing_replica_everywhere() {
        forall(200, |rng| {
            let brokers = rng.range(2, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(2, brokers as u64) as usize;
            let t = ShardTable::build(partitions, brokers, replication, rng.next_u64());
            let r = t.rotated();
            assert_eq!(r.epoch, t.epoch + 1);
            for p in (0..partitions).map(PartitionId) {
                assert_eq!(r.primary(p), t.replica_set(p)[1], "first replica promoted");
                let old: HashSet<_> = t.replica_set(p).iter().collect();
                let new: HashSet<_> = r.replica_set(p).iter().collect();
                assert_eq!(old, new, "rotation keeps replica-set membership");
            }
        });
    }

    #[test]
    fn quorum_is_a_majority() {
        assert_eq!(ShardTable::build(4, 2, 1, 0).quorum(), 1);
        assert_eq!(ShardTable::build(4, 2, 2, 0).quorum(), 2);
        assert_eq!(ShardTable::build(6, 3, 3, 0).quorum(), 2);
        assert_eq!(ShardTable::build(8, 4, 4, 0).quorum(), 3);
    }

    #[test]
    fn client_cache_refreshes_only_on_epoch_advance() {
        let table = ShardTable::build(4, 2, 2, 7);
        let shard = ShardState::shared(table.clone());
        shard.borrow_mut().brokers =
            vec![(ActorId(10), 0), (ActorId(11), 0)];
        let mut client = ShardClient::new(&shard);
        assert_eq!(client.epoch(), 0);
        assert!(!client.refresh(), "no publish, no change");
        let rotated = table.rotated();
        shard.borrow_mut().table = rotated.clone();
        assert_eq!(client.epoch(), 0, "cache is stale until refreshed");
        assert!(client.refresh());
        assert_eq!(client.epoch(), 1);
        assert_eq!(
            client.broker_for(PartitionId(0)).0,
            shard.borrow().brokers[rotated.primary(PartitionId(0))].0
        );
    }
}
