//! Sharding: partitions spread across N brokers, replica sets, rebalance.
//!
//! Everything before this subsystem funnelled through one broker actor
//! (plus one optional backup). Serving real traffic means partitions
//! **sharded** across many brokers with replicated, rebalance-able
//! ownership — the topology the Uber real-time-infrastructure paper
//! describes, operated through the coordinator/broker split of Isah &
//! Zulkernine's ingestion framework. This module is that control plane:
//!
//! * [`ShardTable`] — the versioned partition → replica-set assignment
//!   table. Range-based: each broker owns a contiguous run of `Ns /
//!   broker_count` partitions (seed-rotated so broker 0 is not special),
//!   and partition `p`'s replica set is the `replication_factor` brokers
//!   starting at its primary. Pure function of `(Ns, broker_count,
//!   replication_factor, seed)` — same inputs, same table, on every node.
//! * [`ShardState`] / [`SharedShard`] — the shared blackboard (same
//!   `Rc<RefCell>` idiom as the plasma store) holding the **published**
//!   table plus the broker actor roster. Only the coordinator writes it.
//! * [`ShardClient`] — the cached routing view producers and sources hold:
//!   a table snapshot plus its epoch. Routing decisions use the cache;
//!   [`ShardClient::refresh`] re-snapshots after a
//!   [`crate::proto::RpcReply::WrongShard`] reply or a
//!   [`crate::proto::Msg::ShardEpoch`] notification.
//! * [`BrokerShard`] — the broker-side view: this broker's index, the
//!   partitions it currently serves as primary (mutated by freeze /
//!   promote), and each partition's replica peers.
//! * [`ShardCoordinator`] — the actor that owns the table's lifecycle and
//!   drives live rebalancing.
//!
//! ## The assignment-epoch contract
//!
//! The table carries a monotonically increasing `epoch`. The rules that
//! make cached routing safe:
//!
//! 1. **Clients route on a cached epoch.** A producer or source resolves
//!    `partition → broker` from its snapshot and never blocks on the
//!    coordinator.
//! 2. **Brokers are the authority.** Every data-path request against a
//!    partition the broker does not currently serve as primary is refused
//!    with `WrongShard { epoch }` — never silently served, never panicked.
//!    A quorum-committed append is still acked even if the partition froze
//!    while the acks were in flight (the data is on the replicas; the
//!    hand-off waits for exactly those acks).
//! 3. **Stale clients converge.** On `WrongShard` (or `ShardEpoch`) the
//!    client refreshes its snapshot and retries. Because the coordinator
//!    always publishes the new table after a hand-off, the retry loop
//!    terminates; retries are therefore *unbounded* (counted, backed off)
//!    rather than budgeted like genuine rejections.
//! 4. **Hand-off is drain → checkpoint cursors → reassign → resume.**
//!    Freeze stops the old primary and drains its in-flight replication;
//!    push sources checkpoint their cursors through `PushUnsubscribe`
//!    (the same `SourceSnapshot` cursor primitive checkpointing uses);
//!    promote turns the standing replica into the new primary; publishing
//!    the table resumes routing. Replica logs apply appends at
//!    **primary-assigned offsets**, so the new primary's log is
//!    byte-identical to the old one's and cursors carry over unchanged —
//!    zero loss, zero duplication.
//!
//! ## Fail-over: the emergency epoch
//!
//! Rebalancing is the *cooperative* hand-off; broker death is the
//! *emergency* one. The coordinator doubles as the failure detector: it
//! probes every broker with a heartbeat RPC each `shard_heartbeat_ms`,
//! records the last ack per broker, and declares a broker **dead** when
//! its silence exceeds the `shard_lease_ms` lease. The declaration drives
//! a one-round fail-over, the planned hand-off minus the participant that
//! can no longer cooperate:
//!
//! 1. **Rebuild.** [`ShardTable::failed_over`] removes the corpse from
//!    every replica set and promotes the first surviving replica of each
//!    dead-primary partition — one epoch bump, no other movement. Rows
//!    that contained the dead broker shrink, so quorum arithmetic is
//!    per-partition from here on ([`ShardTable::quorum_of`]).
//! 2. **Notify every survivor** (`ShardFailover` RPC, carrying the new
//!    table and the partitions that broker gains). Survivors install the
//!    roster, purge in-flight replication held on the dead peer —
//!    releasing producer acks wedged on a quorum vote that will never
//!    arrive — and start serving their gained partitions. There is no
//!    freeze phase: the dead primary cannot serve anyway, and by
//!    detection time (a lease, orders of magnitude above any delivery
//!    delay) everything it ever replicated has long been applied.
//! 3. **Publish + mark down.** The down mask in [`ShardState`] is set at
//!    declaration time so clients can distinguish *dead broker* from
//!    *slow broker* ([`ShardClient::actor_down`]); the table publishes
//!    after every survivor acks, and sources get the usual `ShardEpoch`
//!    nudge.
//!
//! No committed record is lost at `replication_factor >= 2`: a quorum ack
//! implies a surviving replica holds every acked byte, and replication
//! fan-out is atomic with the primary append, so even *unacked* appends
//! reach the survivor. Exactly-once across the death is the broker-side
//! idempotence table's job: producers retransmit a deadline-expired RPC
//! under the **same id**, and whichever broker now owns the partition
//! re-acks recorded totals instead of re-appending (see `crate::broker`).

use std::cell::RefCell;
use std::collections::HashSet;
use std::rc::Rc;

use crate::config::CostModel;
use crate::net::{NodeId, SharedNetwork};
use crate::proto::{Msg, PartitionId, RpcKind, RpcReply, RpcRequest};
use crate::sim::{Actor, ActorId, Ctx, Time};

// ---------------------------------------------------------------------------
// The assignment table
// ---------------------------------------------------------------------------

/// The versioned partition → replica-set assignment table.
///
/// `replicas[p][0]` is partition `p`'s primary; the rest of the row are
/// its standing replicas. See the module docs for the epoch contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardTable {
    /// Monotone version; bumped by every reassignment.
    pub epoch: u64,
    /// Broker count the table spans.
    brokers: usize,
    /// Replica-set size (`replication_factor`).
    replication: usize,
    /// Per-partition replica sets, primary first.
    replicas: Vec<Vec<usize>>,
}

impl ShardTable {
    /// Build the initial table: contiguous ranges of `partitions /
    /// brokers` partitions, the range→broker mapping rotated by the seed,
    /// replica `j` of a partition on `(primary + j) % brokers`. Pure —
    /// every node building with the same inputs gets the same table.
    pub fn build(partitions: usize, brokers: usize, replication: usize, seed: u64) -> Self {
        assert!(brokers > 0 && partitions > 0, "shard table needs brokers and partitions");
        assert!(
            partitions % brokers == 0,
            "Ns={partitions} must divide across broker_count={brokers}"
        );
        assert!(
            (1..=brokers).contains(&replication),
            "replication_factor={replication} must be in 1..=broker_count={brokers}"
        );
        let span = partitions / brokers;
        let offset = (seed % brokers as u64) as usize;
        let replicas = (0..partitions)
            .map(|p| {
                let primary = (p / span + offset) % brokers;
                (0..replication).map(|j| (primary + j) % brokers).collect()
            })
            .collect();
        ShardTable { epoch: 0, brokers, replication, replicas }
    }

    pub fn partitions(&self) -> usize {
        self.replicas.len()
    }

    pub fn brokers(&self) -> usize {
        self.brokers
    }

    pub fn replication(&self) -> usize {
        self.replication
    }

    /// The broker currently serving `p` as primary.
    pub fn primary(&self, p: PartitionId) -> usize {
        self.replicas[p.0][0]
    }

    /// `p`'s full replica set, primary first.
    pub fn replica_set(&self, p: PartitionId) -> &[usize] {
        &self.replicas[p.0]
    }

    /// Does broker `b` hold a replica (primary or standing) of `p`?
    pub fn hosts(&self, p: PartitionId, b: usize) -> bool {
        self.replicas[p.0].contains(&b)
    }

    /// Acks (including the primary's own append) that commit a write:
    /// a majority of the **configured** replica-set size. Build-time
    /// uniform; after a fail-over shrinks individual rows, use
    /// [`Self::quorum_of`] for the partition actually written.
    pub fn quorum(&self) -> usize {
        self.replication / 2 + 1
    }

    /// The majority quorum of `p`'s *current* replica set. Equal to
    /// [`Self::quorum`] until a fail-over removes a dead broker from the
    /// row — then the survivors' shrunken majority commits the write.
    pub fn quorum_of(&self, p: PartitionId) -> usize {
        self.replicas[p.0].len() / 2 + 1
    }

    /// The partitions broker `b` currently serves as primary, ascending.
    pub fn primaries_of(&self, b: usize) -> Vec<PartitionId> {
        (0..self.replicas.len())
            .map(PartitionId)
            .filter(|&p| self.primary(p) == b)
            .collect()
    }

    /// The rebalanced table the coordinator hands off to: every replica
    /// set rotated left, so each partition's standing first replica
    /// becomes its primary. Requires `replication_factor >= 2` (with one
    /// replica there is nothing to promote).
    pub fn rotated(&self) -> ShardTable {
        assert!(self.replication >= 2, "rotation promotes the standing replica");
        let replicas = self
            .replicas
            .iter()
            .map(|set| {
                let mut s = set.clone();
                s.rotate_left(1);
                s
            })
            .collect();
        ShardTable {
            epoch: self.epoch + 1,
            brokers: self.brokers,
            replication: self.replication,
            replicas,
        }
    }

    /// Grow the fleet by one broker with minimal movement: the new broker
    /// takes `ceil(P / (N+1))` partitions, stolen one at a time from
    /// whichever broker is most loaded; everything else stays put. The
    /// stability property the tests pin: adding a broker never moves more
    /// than `ceil(P / N_new)` primaries.
    pub fn grown(&self) -> ShardTable {
        let new_brokers = self.brokers + 1;
        let p_total = self.replicas.len();
        let target = p_total.div_ceil(new_brokers);
        let mut primaries: Vec<usize> = (0..p_total).map(|p| self.replicas[p][0]).collect();
        let mut load = vec![0usize; new_brokers];
        for &b in &primaries {
            load[b] += 1;
        }
        for _ in 0..target {
            let donor = (0..self.brokers).max_by_key(|&b| load[b]).expect("brokers > 0");
            if load[donor] == 0 {
                break;
            }
            let victim = (0..p_total)
                .rev()
                .find(|&p| primaries[p] == donor)
                .expect("donor has load");
            primaries[victim] = self.brokers;
            load[donor] -= 1;
            load[self.brokers] += 1;
        }
        let replicas = primaries
            .iter()
            .map(|&primary| (0..self.replication).map(|j| (primary + j) % new_brokers).collect())
            .collect();
        ShardTable {
            epoch: self.epoch + 1,
            brokers: new_brokers,
            replication: self.replication,
            replicas,
        }
    }

    /// The emergency table after broker `dead` is declared dead: the
    /// corpse is removed from every replica set, which promotes the first
    /// surviving replica of each partition it served as primary. Exactly
    /// one epoch bump; no other primary moves. Requires every affected
    /// partition to keep at least one live replica (`replication_factor
    /// >= 2` guarantees it for a single death).
    pub fn failed_over(&self, dead: usize) -> ShardTable {
        assert!(dead < self.brokers, "dead broker index out of range");
        assert!(self.replication >= 2, "fail-over promotes the standing replica");
        let replicas: Vec<Vec<usize>> = self
            .replicas
            .iter()
            .map(|set| {
                let s: Vec<usize> = set.iter().copied().filter(|&b| b != dead).collect();
                assert!(!s.is_empty(), "partition lost its last replica");
                s
            })
            .collect();
        ShardTable {
            epoch: self.epoch + 1,
            brokers: self.brokers,
            replication: self.replication,
            replicas,
        }
    }

    /// Reassemble a table from raw parts (the real-plane wire codec's
    /// decode side; everything else builds through [`Self::build`] and
    /// the transition methods).
    pub fn from_parts(
        epoch: u64,
        brokers: usize,
        replication: usize,
        replicas: Vec<Vec<usize>>,
    ) -> Self {
        ShardTable { epoch, brokers, replication, replicas }
    }

    /// How many partitions changed primary between two tables.
    pub fn moved_primaries(&self, other: &ShardTable) -> usize {
        assert_eq!(self.replicas.len(), other.replicas.len(), "comparable tables");
        (0..self.replicas.len())
            .filter(|&p| self.replicas[p][0] != other.replicas[p][0])
            .count()
    }
}

// ---------------------------------------------------------------------------
// Shared state + client cache
// ---------------------------------------------------------------------------

/// The published shard view: the current table plus the broker roster.
/// Written only by the [`ShardCoordinator`] (after a complete hand-off);
/// read by every [`ShardClient`] refresh.
#[derive(Debug)]
pub struct ShardState {
    pub table: ShardTable,
    /// Broker actors by table index.
    pub brokers: Vec<(ActorId, NodeId)>,
    /// Liveness mask by table index, set by the coordinator at the moment
    /// a broker is *declared* dead — before the rebuilt table publishes —
    /// so deadline-expired clients can tell a dead destination from a
    /// merely slow one. Empty until the first declaration.
    pub down: Vec<bool>,
}

/// Shared handle (same idiom as the plasma store blackboard).
pub type SharedShard = Rc<RefCell<ShardState>>;

impl ShardState {
    pub fn shared(table: ShardTable) -> SharedShard {
        Rc::new(RefCell::new(ShardState { table, brokers: Vec::new(), down: Vec::new() }))
    }

    /// Is the broker at table index `b` declared dead?
    pub fn is_down(&self, b: usize) -> bool {
        self.down.get(b).copied().unwrap_or(false)
    }
}

/// A client's cached routing view (producers and sources hold one each).
/// Routing never touches the shared state; [`ShardClient::refresh`]
/// re-snapshots after a staleness signal.
#[derive(Debug, Clone)]
pub struct ShardClient {
    shard: SharedShard,
    table: ShardTable,
    brokers: Vec<(ActorId, NodeId)>,
}

impl ShardClient {
    pub fn new(shard: &SharedShard) -> Self {
        let s = shard.borrow();
        ShardClient { shard: shard.clone(), table: s.table.clone(), brokers: s.brokers.clone() }
    }

    /// The cached assignment epoch.
    pub fn epoch(&self) -> u64 {
        self.table.epoch
    }

    /// Resolve `p`'s primary broker under the cached table.
    pub fn broker_for(&self, p: PartitionId) -> (ActorId, NodeId) {
        self.brokers[self.table.primary(p)]
    }

    /// The cached table (for grouping partitions by destination).
    pub fn table(&self) -> &ShardTable {
        &self.table
    }

    /// Re-snapshot the published view; `true` if the epoch advanced.
    pub fn refresh(&mut self) -> bool {
        let s = self.shard.borrow();
        let advanced = s.table.epoch > self.table.epoch;
        if advanced {
            self.table = s.table.clone();
            self.brokers = s.brokers.clone();
        }
        advanced
    }

    /// Has the coordinator declared broker actor `a` dead? Reads the
    /// **live** shared view, not the cache: the down mask is set at
    /// declaration time, possibly before the rebuilt table publishes, and
    /// a deadline-expired client needs the freshest answer to decide
    /// between "retransmit now" and "wait for the next epoch".
    pub fn actor_down(&self, a: ActorId) -> bool {
        let s = self.shard.borrow();
        s.brokers.iter().enumerate().any(|(b, &(id, _))| id == a && s.is_down(b))
    }
}

// ---------------------------------------------------------------------------
// Broker-side view
// ---------------------------------------------------------------------------

/// What a shard broker knows about its place in the table: its index, the
/// partitions it currently serves as primary (freeze removes, promote
/// adds), and each partition's replica peers for quorum fan-out.
#[derive(Debug)]
pub struct BrokerShard {
    /// This broker's index in the table.
    pub index: usize,
    /// The assignment epoch this broker last heard (freeze/promote carry
    /// it forward; `WrongShard` replies report it).
    pub epoch: u64,
    /// Partitions currently served as primary.
    pub primaries: HashSet<PartitionId>,
    /// The replica-membership view this broker fans quorum writes by.
    /// Build-time membership is stable across *rotations*; a fail-over
    /// replaces it wholesale (the `ShardFailover` RPC carries the rebuilt
    /// table with the dead peer filtered out of every row).
    pub table: ShardTable,
    /// Broker roster by table index (includes self at `index`). Stable —
    /// a dead peer keeps its slot, the table just stops referencing it.
    pub peers: Vec<(ActorId, NodeId)>,
}

impl BrokerShard {
    pub fn new(index: usize, table: ShardTable, peers: Vec<(ActorId, NodeId)>) -> Self {
        let primaries = table.primaries_of(index).into_iter().collect();
        BrokerShard { index, epoch: table.epoch, primaries, table, peers }
    }

    /// Is this broker the current primary for `p`?
    pub fn is_primary(&self, p: PartitionId) -> bool {
        self.primaries.contains(&p)
    }

    /// The non-self replica peers of `p` with their table indices, for
    /// replication fan-out (the index is remembered per in-flight
    /// replicate so a fail-over can purge exactly the rids held on the
    /// dead peer).
    pub fn replica_peers(&self, p: PartitionId) -> Vec<(usize, (ActorId, NodeId))> {
        self.table
            .replica_set(p)
            .iter()
            .filter(|&&b| b != self.index)
            .map(|&b| (b, self.peers[b]))
            .collect()
    }

    /// Peer acks needed before a write to `p` commits (the primary's own
    /// append is the first quorum vote). Per-partition: rows shrink after
    /// a fail-over, and a one-survivor row commits on the primary alone.
    pub fn needed_peer_acks(&self, p: PartitionId) -> usize {
        self.table.quorum_of(p) - 1
    }
}

// ---------------------------------------------------------------------------
// The coordinator actor
// ---------------------------------------------------------------------------

/// Static coordinator wiring.
#[derive(Debug, Clone)]
pub struct ShardCoordinatorParams {
    /// Node the coordinator runs on (the colocated worker node).
    pub node: NodeId,
    /// Force one live rebalance (table rotation) at this virtual time;
    /// 0 = own the table but never move it.
    pub rebalance_at: Time,
    /// Failure detector: heartbeat probe period (ns); 0 = detector off
    /// (the launcher arms it whenever the topology could act on a death).
    pub heartbeat: Time,
    /// Failure detector: a broker silent for longer than this lease (ns)
    /// is declared dead and failed over.
    pub lease: Time,
    /// Source actors to notify when a new table publishes.
    pub sources: Vec<ActorId>,
    pub cost: CostModel,
}

/// End-of-run rebalance accounting (exported as gauges by the launcher).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Completed cooperative hand-offs (rebalances — fail-overs count
    /// separately).
    pub rebalances: u64,
    /// Primaries moved across all cooperative hand-offs.
    pub partitions_moved: u64,
    /// Freeze-trigger → table-publish span of the last hand-off (ns).
    pub handoff_ns: u64,
    /// Completed emergency fail-overs (brokers declared dead).
    pub failovers: u64,
    /// Partitions promoted onto survivors across all fail-overs.
    pub promotions: u64,
    /// Last-ack → declaration span of the last fail-over (ns): how long
    /// the detector took to notice the silence.
    pub detection_ns: u64,
}

/// The hand-off state machine: freeze the losing primaries, wait for
/// their drains, promote the gaining replicas, publish. The emergency
/// flavor (`FailingOver`) skips the freeze — the dead primary cannot
/// drain — and promotes in the same round that installs the roster.
enum Handoff {
    Idle,
    Freezing { table: ShardTable, acks: usize, expect: usize, started: Time },
    Promoting { table: ShardTable, acks: usize, expect: usize, started: Time },
    FailingOver { table: ShardTable, acks: usize, expect: usize, started: Time },
}

/// The actor that owns the assignment table's lifecycle: it publishes the
/// initial table (built by the launcher), and on `rebalance_at` drives
/// the live hand-off protocol — drain (freeze) → reassign (promote) →
/// resume (publish + notify sources). Producers need no notification:
/// their next `WrongShard` retry refreshes against the published table.
pub struct ShardCoordinator {
    params: ShardCoordinatorParams,
    shard: SharedShard,
    net: SharedNetwork,
    handoff: Handoff,
    next_rpc: u64,
    stats: ShardStats,
    /// Failure detector: last heartbeat ack per broker (table index).
    last_ack: Vec<Time>,
    /// Local mirror of the published down mask.
    down: Vec<bool>,
    /// In-flight heartbeat rpc id → broker index (acks from a broker
    /// declared dead in the meantime are dropped by the mask check).
    hb_rids: std::collections::HashMap<u64, usize>,
}

impl ShardCoordinator {
    pub fn new(params: ShardCoordinatorParams, shard: SharedShard, net: SharedNetwork) -> Self {
        Self {
            params,
            shard,
            net,
            handoff: Handoff::Idle,
            next_rpc: 0,
            stats: ShardStats::default(),
            last_ack: Vec::new(),
            down: Vec::new(),
            hb_rids: std::collections::HashMap::new(),
        }
    }

    pub fn stats(&self) -> ShardStats {
        self.stats.clone()
    }

    fn rpc(&mut self, to: (ActorId, NodeId), kind: RpcKind, ctx: &mut Ctx<'_, Msg>) {
        let id = self.next_rpc;
        self.next_rpc += 1;
        let deliver = self.net.borrow_mut().send_control(ctx.now(), self.params.node, to.1);
        ctx.send_at(
            deliver,
            to.0,
            Msg::rpc(RpcRequest {
                id,
                reply_to: ctx.self_id(),
                from_node: self.params.node,
                kind,
            }),
        );
    }

    /// Start the hand-off: compute the rotated table and freeze every
    /// broker that loses a primary under it.
    fn begin_rebalance(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let (old, brokers) = {
            let s = self.shard.borrow();
            (s.table.clone(), s.brokers.clone())
        };
        let table = old.rotated();
        self.stats.partitions_moved += old.moved_primaries(&table) as u64;
        let mut expect = 0;
        for (b, &peer) in brokers.iter().enumerate() {
            let lost: Vec<PartitionId> = old
                .primaries_of(b)
                .into_iter()
                .filter(|&p| table.primary(p) != b)
                .collect();
            if !lost.is_empty() {
                self.rpc(peer, RpcKind::ShardFreeze { epoch: table.epoch, partitions: lost }, ctx);
                expect += 1;
            }
        }
        if expect == 0 {
            self.stats.rebalances += 1;
            self.publish(table, ctx);
        } else {
            self.handoff = Handoff::Freezing { table, acks: 0, expect, started: ctx.now() };
        }
    }

    /// All drains complete: promote every broker that gains a primary.
    fn begin_promote(&mut self, table: ShardTable, started: Time, ctx: &mut Ctx<'_, Msg>) {
        let (old, brokers) = {
            let s = self.shard.borrow();
            (s.table.clone(), s.brokers.clone())
        };
        let mut expect = 0;
        for (b, &peer) in brokers.iter().enumerate() {
            let gained: Vec<PartitionId> = table
                .primaries_of(b)
                .into_iter()
                .filter(|&p| old.primary(p) != b)
                .collect();
            if !gained.is_empty() {
                self.rpc(
                    peer,
                    RpcKind::ShardPromote { epoch: table.epoch, partitions: gained },
                    ctx,
                );
                expect += 1;
            }
        }
        assert!(expect > 0, "a hand-off that froze primaries must promote them somewhere");
        self.handoff = Handoff::Promoting { table, acks: 0, expect, started };
    }

    /// Resume: publish the new table and nudge the sources (producers
    /// converge through WrongShard retries on their own).
    fn publish(&mut self, table: ShardTable, ctx: &mut Ctx<'_, Msg>) {
        let epoch = table.epoch;
        self.shard.borrow_mut().table = table;
        for &s in &self.params.sources {
            ctx.send_in(self.params.cost.notify_ns, s, Msg::ShardEpoch { epoch });
        }
        self.handoff = Handoff::Idle;
    }

    /// One detector tick: declare the first broker whose lease expired
    /// (single-failure scope — one corpse per tick, and only from Idle so
    /// a declaration never races a hand-off in flight), then probe every
    /// broker still considered live and re-arm the tick.
    fn on_heartbeat_tick(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let brokers = self.shard.borrow().brokers.clone();
        let now = ctx.now();
        if matches!(self.handoff, Handoff::Idle) {
            let expired = (0..brokers.len()).find(|&b| {
                !self.down[b] && now.saturating_sub(self.last_ack[b]) > self.params.lease
            });
            if let Some(dead) = expired {
                self.declare_dead(dead, ctx);
            }
        }
        self.hb_rids.retain(|_, b| !self.down[*b]);
        for (b, &peer) in brokers.iter().enumerate() {
            if !self.down[b] {
                let id = self.next_rpc;
                self.hb_rids.insert(id, b);
                self.rpc(peer, RpcKind::Heartbeat, ctx);
            }
        }
        ctx.send_self_in(self.params.heartbeat, Msg::Timer(1));
    }

    /// The emergency round: rebuild the table past the corpse, mark it
    /// down (immediately — clients consult the mask on RPC deadlines),
    /// and send every survivor the new roster plus its gained primaries.
    fn declare_dead(&mut self, dead: usize, ctx: &mut Ctx<'_, Msg>) {
        let (old, brokers) = {
            let s = self.shard.borrow();
            (s.table.clone(), s.brokers.clone())
        };
        let table = old.failed_over(dead);
        self.stats.failovers += 1;
        self.stats.promotions += old.moved_primaries(&table) as u64;
        self.stats.detection_ns = ctx.now().saturating_sub(self.last_ack[dead]);
        self.down[dead] = true;
        {
            let mut s = self.shard.borrow_mut();
            if s.down.len() < brokers.len() {
                s.down.resize(brokers.len(), false);
            }
            s.down[dead] = true;
        }
        let mut expect = 0;
        for (b, &peer) in brokers.iter().enumerate() {
            if self.down[b] {
                continue;
            }
            let gained: Vec<PartitionId> = table
                .primaries_of(b)
                .into_iter()
                .filter(|&p| old.primary(p) != b)
                .collect();
            self.rpc(
                peer,
                RpcKind::ShardFailover { epoch: table.epoch, dead, table: table.clone(), gained },
                ctx,
            );
            expect += 1;
        }
        assert!(expect > 0, "fail-over needs at least one surviving broker");
        self.handoff = Handoff::FailingOver { table, acks: 0, expect, started: ctx.now() };
    }

    fn on_reply(&mut self, id: u64, reply: RpcReply, ctx: &mut Ctx<'_, Msg>) {
        if let Some(b) = self.hb_rids.remove(&id) {
            match reply {
                RpcReply::HeartbeatAck { .. } => {
                    if !self.down[b] {
                        self.last_ack[b] = ctx.now();
                    }
                }
                other => panic!("shard coordinator: heartbeat answered with {other:?}"),
            }
            return;
        }
        match reply {
            RpcReply::FreezeAck { .. } => {
                let done = match &mut self.handoff {
                    Handoff::Freezing { acks, expect, .. } => {
                        *acks += 1;
                        *acks == *expect
                    }
                    _ => panic!("shard coordinator: freeze ack outside a freeze phase"),
                };
                if !done {
                    return;
                }
                let Handoff::Freezing { table, started, .. } =
                    std::mem::replace(&mut self.handoff, Handoff::Idle)
                else {
                    unreachable!()
                };
                self.begin_promote(table, started, ctx);
            }
            RpcReply::PromoteAck { .. } => {
                let done = match &mut self.handoff {
                    Handoff::Promoting { acks, expect, .. } => {
                        *acks += 1;
                        *acks == *expect
                    }
                    _ => panic!("shard coordinator: promote ack outside a promote phase"),
                };
                if !done {
                    return;
                }
                let Handoff::Promoting { table, started, .. } =
                    std::mem::replace(&mut self.handoff, Handoff::Idle)
                else {
                    unreachable!()
                };
                self.stats.handoff_ns = ctx.now() - started;
                self.stats.rebalances += 1;
                self.publish(table, ctx);
            }
            RpcReply::FailoverAck { .. } => {
                let done = match &mut self.handoff {
                    Handoff::FailingOver { acks, expect, .. } => {
                        *acks += 1;
                        *acks == *expect
                    }
                    _ => panic!("shard coordinator: fail-over ack outside a fail-over"),
                };
                if !done {
                    return;
                }
                let Handoff::FailingOver { table, started, .. } =
                    std::mem::replace(&mut self.handoff, Handoff::Idle)
                else {
                    unreachable!()
                };
                self.stats.handoff_ns = ctx.now() - started;
                self.publish(table, ctx);
            }
            RpcReply::Error { reason } => {
                panic!("shard coordinator: broker refused a hand-off step: {reason}")
            }
            other => panic!("shard coordinator: unexpected reply {other:?}"),
        }
    }
}

impl Actor<Msg> for ShardCoordinator {
    fn on_start(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.params.rebalance_at > 0 {
            ctx.send_self_in(self.params.rebalance_at, Msg::Timer(0));
        }
        if self.params.heartbeat > 0 {
            let n = self.shard.borrow().brokers.len();
            self.last_ack = vec![ctx.now(); n];
            self.down = vec![false; n];
            ctx.send_self_in(self.params.heartbeat, Msg::Timer(1));
        }
    }

    fn on_event(&mut self, msg: Msg, ctx: &mut Ctx<'_, Msg>) {
        match msg {
            Msg::Timer(0) => {
                assert!(
                    matches!(self.handoff, Handoff::Idle),
                    "rebalance trigger while a hand-off is in flight"
                );
                self.begin_rebalance(ctx);
            }
            Msg::Timer(1) => self.on_heartbeat_tick(ctx),
            Msg::Timer(t) => panic!("shard coordinator: unknown timer tag {t}"),
            Msg::Reply(env) => self.on_reply(env.id, env.reply, ctx),
            other => panic!("shard coordinator: unexpected {other:?}"),
        }
    }

    fn label(&self) -> String {
        "shard-coordinator".into()
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

// ---------------------------------------------------------------------------
// Table property tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::proptest::forall;

    #[test]
    fn build_is_deterministic_in_its_inputs() {
        forall(200, |rng| {
            let brokers = rng.range(1, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(1, brokers as u64) as usize;
            let seed = rng.next_u64();
            let a = ShardTable::build(partitions, brokers, replication, seed);
            let b = ShardTable::build(partitions, brokers, replication, seed);
            assert_eq!(a, b, "same inputs, same table");
        });
    }

    #[test]
    fn every_partition_has_a_distinct_full_replica_set() {
        forall(200, |rng| {
            let brokers = rng.range(1, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(1, brokers as u64) as usize;
            let t = ShardTable::build(partitions, brokers, replication, rng.next_u64());
            for p in (0..partitions).map(PartitionId) {
                let set = t.replica_set(p);
                assert_eq!(set.len(), replication);
                let distinct: HashSet<_> = set.iter().collect();
                assert_eq!(distinct.len(), replication, "replicas land on distinct brokers");
                assert!(t.hosts(p, t.primary(p)));
            }
        });
    }

    #[test]
    fn ranges_balance_exactly() {
        forall(200, |rng| {
            let brokers = rng.range(1, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let t = ShardTable::build(partitions, brokers, 1, rng.next_u64());
            for b in 0..brokers {
                assert_eq!(t.primaries_of(b).len(), partitions / brokers);
            }
        });
    }

    #[test]
    fn growing_the_fleet_moves_at_most_a_fair_share() {
        forall(300, |rng| {
            let brokers = rng.range(1, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(1, brokers as u64) as usize;
            let t = ShardTable::build(partitions, brokers, replication, rng.next_u64());
            let g = t.grown();
            assert_eq!(g.brokers(), brokers + 1);
            assert_eq!(g.epoch, t.epoch + 1);
            let moved = t.moved_primaries(&g);
            let bound = partitions.div_ceil(brokers + 1);
            assert!(
                moved <= bound,
                "grow moved {moved} primaries, bound ceil({partitions}/{}) = {bound}",
                brokers + 1
            );
            // Everything that moved landed on the new broker.
            assert_eq!(g.primaries_of(brokers).len(), moved);
        });
    }

    #[test]
    fn rotation_promotes_the_standing_replica_everywhere() {
        forall(200, |rng| {
            let brokers = rng.range(2, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(2, brokers as u64) as usize;
            let t = ShardTable::build(partitions, brokers, replication, rng.next_u64());
            let r = t.rotated();
            assert_eq!(r.epoch, t.epoch + 1);
            for p in (0..partitions).map(PartitionId) {
                assert_eq!(r.primary(p), t.replica_set(p)[1], "first replica promoted");
                let old: HashSet<_> = t.replica_set(p).iter().collect();
                let new: HashSet<_> = r.replica_set(p).iter().collect();
                assert_eq!(old, new, "rotation keeps replica-set membership");
            }
        });
    }

    #[test]
    fn failover_leaves_a_live_primary_everywhere() {
        forall(300, |rng| {
            let brokers = rng.range(2, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(2, brokers as u64) as usize;
            let t = ShardTable::build(partitions, brokers, replication, rng.next_u64());
            let dead = rng.range(0, brokers as u64 - 1) as usize;
            let f = t.failed_over(dead);
            assert_eq!(f.epoch, t.epoch + 1, "exactly one epoch bump");
            assert_eq!(f.brokers(), t.brokers(), "the roster keeps its slots");
            for p in (0..partitions).map(PartitionId) {
                assert_ne!(f.primary(p), dead, "every partition has a live primary");
                assert!(
                    !f.replica_set(p).contains(&dead),
                    "no replica set references the dead broker"
                );
                // Membership is the old set minus the corpse, order kept.
                let expect: Vec<usize> =
                    t.replica_set(p).iter().copied().filter(|&b| b != dead).collect();
                assert_eq!(f.replica_set(p), expect.as_slice());
                // Dead-primary partitions promote their standing replica;
                // everything else stays put.
                if t.primary(p) == dead {
                    assert_eq!(f.primary(p), t.replica_set(p)[1], "standing replica promoted");
                } else {
                    assert_eq!(f.primary(p), t.primary(p), "live primaries do not move");
                }
            }
        });
    }

    #[test]
    fn failover_shrinks_quorums_only_where_the_dead_broker_lived() {
        forall(300, |rng| {
            let brokers = rng.range(2, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(2, brokers as u64) as usize;
            let t = ShardTable::build(partitions, brokers, replication, rng.next_u64());
            let dead = rng.range(0, brokers as u64 - 1) as usize;
            let f = t.failed_over(dead);
            for p in (0..partitions).map(PartitionId) {
                if t.hosts(p, dead) {
                    assert_eq!(f.replica_set(p).len(), replication - 1);
                    assert_eq!(f.quorum_of(p), (replication - 1) / 2 + 1);
                } else {
                    assert_eq!(f.quorum_of(p), t.quorum_of(p), "untouched rows keep quorum");
                }
            }
        });
    }

    #[test]
    fn failover_moves_exactly_the_dead_brokers_primaries() {
        forall(200, |rng| {
            let brokers = rng.range(2, 8) as usize;
            let partitions = brokers * rng.range(1, 6) as usize;
            let replication = rng.range(2, brokers as u64) as usize;
            let t = ShardTable::build(partitions, brokers, replication, rng.next_u64());
            let dead = rng.range(0, brokers as u64 - 1) as usize;
            let f = t.failed_over(dead);
            assert_eq!(f.moved_primaries(&t), t.primaries_of(dead).len());
            assert!(f.primaries_of(dead).is_empty(), "the corpse serves nothing");
        });
    }

    #[test]
    fn quorum_is_a_majority() {
        assert_eq!(ShardTable::build(4, 2, 1, 0).quorum(), 1);
        assert_eq!(ShardTable::build(4, 2, 2, 0).quorum(), 2);
        assert_eq!(ShardTable::build(6, 3, 3, 0).quorum(), 2);
        assert_eq!(ShardTable::build(8, 4, 4, 0).quorum(), 3);
    }

    #[test]
    fn client_cache_refreshes_only_on_epoch_advance() {
        let table = ShardTable::build(4, 2, 2, 7);
        let shard = ShardState::shared(table.clone());
        shard.borrow_mut().brokers =
            vec![(ActorId(10), 0), (ActorId(11), 0)];
        let mut client = ShardClient::new(&shard);
        assert_eq!(client.epoch(), 0);
        assert!(!client.refresh(), "no publish, no change");
        let rotated = table.rotated();
        shard.borrow_mut().table = rotated.clone();
        assert_eq!(client.epoch(), 0, "cache is stale until refreshed");
        assert!(client.refresh());
        assert_eq!(client.epoch(), 1);
        assert_eq!(
            client.broker_for(PartitionId(0)).0,
            shard.borrow().brokers[rotated.primary(PartitionId(0))].0
        );
    }

    #[test]
    fn down_mask_is_visible_before_the_table_publishes() {
        let table = ShardTable::build(4, 2, 2, 7);
        let shard = ShardState::shared(table);
        shard.borrow_mut().brokers = vec![(ActorId(10), 0), (ActorId(11), 0)];
        let client = ShardClient::new(&shard);
        assert!(!client.actor_down(ActorId(11)));
        // The coordinator marks the corpse at declaration time — no epoch
        // bump yet — and deadline-expired clients must see it live.
        {
            let mut s = shard.borrow_mut();
            s.down = vec![false, true];
        }
        assert!(client.actor_down(ActorId(11)));
        assert!(!client.actor_down(ActorId(10)));
        assert!(!client.actor_down(ActorId(99)), "unknown actors are not down");
    }
}
