//! Log2-bucketed latency histograms — the fixed-footprint aggregation the
//! tracer folds every stage delta into.
//!
//! A histogram is 64 buckets: bucket 0 holds exact zeros, bucket `i`
//! (1..=63) holds values in `[2^(i-1), 2^i)` nanoseconds. Recording is a
//! leading-zeros computation and one increment — no allocation, no
//! per-sample storage — so per-record tracing stays cheap even at
//! `trace_sample_permille=1000`. Percentiles are nearest-rank over the
//! cumulative bucket counts and report the *inclusive upper bound* of the
//! selected bucket (at most 2x the true sample, exact at bucket edges);
//! the resolution trade is deliberate: a tail estimate that never
//! under-reports, from 512 bytes of state.
//!
//! Histograms from different entities (sources, partitions, tasks) merge
//! by elementwise bucket addition ([`LatencyHistogram::merge`]), which is
//! exact — merging loses nothing, unlike percentile-of-percentiles.

/// Number of log2 buckets: bucket 0 + one per bit of a `u64` delta.
pub const BUCKETS: usize = 64;

/// A fixed-size log2 histogram of nanosecond deltas.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: [u64; BUCKETS],
    count: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { buckets: [0; BUCKETS], count: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// The bucket a delta lands in: 0 for 0, else `64 - leading_zeros`
    /// capped to the last bucket — bucket `i` covers `[2^(i-1), 2^i)`.
    pub fn bucket_of(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            (64 - ns.leading_zeros() as usize).min(BUCKETS - 1)
        }
    }

    /// Inclusive upper bound of a bucket (what percentiles report).
    pub fn bucket_upper(bucket: usize) -> u64 {
        if bucket == 0 {
            0
        } else if bucket >= BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << bucket) - 1
        }
    }

    /// Record one delta.
    pub fn record(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
    }

    /// Samples recorded (including merged-in ones).
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Fold another histogram in — exact (bucketwise addition).
    pub fn merge(&mut self, other: &Self) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
    }

    /// Nearest-rank percentile (`pct` in 0..=100), as the inclusive upper
    /// bound of the bucket holding that rank. 0 on an empty histogram.
    pub fn percentile(&self, pct: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((pct / 100.0) * (self.count as f64 - 1.0)).round() as u64;
        let rank = rank.min(self.count - 1);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if b > 0 && seen > rank {
                return Self::bucket_upper(i);
            }
        }
        Self::bucket_upper(BUCKETS - 1)
    }
}

/// Percentile summary of one stage, merged across entities.
#[derive(Debug, Clone)]
pub struct StageStat {
    pub stage: super::Stage,
    pub count: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub p999_ns: u64,
}

impl StageStat {
    pub fn from_hist(stage: super::Stage, h: &LatencyHistogram) -> Self {
        StageStat {
            stage,
            count: h.count(),
            p50_ns: h.percentile(50.0),
            p95_ns: h.percentile(95.0),
            p99_ns: h.percentile(99.0),
            p999_ns: h.percentile(99.9),
        }
    }
}

/// The end-of-run latency summary carried in `RunSummary` — one
/// [`StageStat`] per stage that recorded any sample.
#[derive(Debug, Clone, Default)]
pub struct LatencyReport {
    pub stages: Vec<StageStat>,
    /// Spans that completed the full produce → emit life.
    pub spans_completed: u64,
    /// Spans opened but still in flight (or dropped by a fault) at the end.
    pub spans_dropped: u64,
}

impl LatencyReport {
    pub fn stage(&self, stage: super::Stage) -> Option<&StageStat> {
        self.stages.iter().find(|s| s.stage == stage)
    }
}
