//! The structured JSONL trace/event sink.
//!
//! Every traced happening — a completed span, a checkpoint epoch, a hybrid
//! switch-over, a fault or a restore — is buffered as a [`TraceEvent`] in
//! DES order and flushed to `trace_out` as one JSON object per line when
//! the run finishes. The JSON is hand-rolled like `BENCH_hotpath.json`
//! (the offline vendor set has no serde) and every field is an integer or
//! a short literal string, so the file is byte-deterministic on a fixed
//! seed: two runs of the same config diff empty — the replay contract the
//! trace tests pin.

use crate::sim::Time;

/// One line of the JSONL sink, in the order the simulation produced it.
#[derive(Debug, Clone)]
pub enum TraceEvent {
    /// A sampled record batch completed its life. Timestamps are virtual
    /// nanoseconds; `source`/`task` are logical task indices.
    Span {
        partition: u64,
        offset: u64,
        source: usize,
        task: usize,
        produced: Time,
        appended: Time,
        notified: Time,
        handoff: Time,
        emitted: Time,
    },
    /// An aligned checkpoint epoch completed.
    Epoch { epoch: u64, at: Time, span_ns: u64 },
    /// The hybrid source switched mechanisms.
    Switch { task: usize, to_push: bool, at: Time },
    /// Fault injection killed a victim.
    Fault { kind: &'static str, at: Time },
    /// Recovery completed (rollback + replay ready).
    Restore { at: Time, recovery_ns: u64 },
}

impl TraceEvent {
    /// One JSON object, no trailing newline.
    pub fn to_json(&self) -> String {
        match self {
            TraceEvent::Span {
                partition,
                offset,
                source,
                task,
                produced,
                appended,
                notified,
                handoff,
                emitted,
            } => format!(
                "{{\"type\":\"span\",\"partition\":{partition},\"offset\":{offset},\
                 \"source\":{source},\"task\":{task},\"produced\":{produced},\
                 \"appended\":{appended},\"notified\":{notified},\
                 \"handoff\":{handoff},\"emitted\":{emitted}}}"
            ),
            TraceEvent::Epoch { epoch, at, span_ns } => format!(
                "{{\"type\":\"epoch\",\"epoch\":{epoch},\"at\":{at},\"span_ns\":{span_ns}}}"
            ),
            TraceEvent::Switch { task, to_push, at } => format!(
                "{{\"type\":\"switch\",\"task\":{task},\"to\":\"{}\",\"at\":{at}}}",
                if *to_push { "push" } else { "pull" }
            ),
            TraceEvent::Fault { kind, at } => {
                format!("{{\"type\":\"fault\",\"kind\":\"{kind}\",\"at\":{at}}}")
            }
            TraceEvent::Restore { at, recovery_ns } => format!(
                "{{\"type\":\"restore\",\"at\":{at},\"recovery_ns\":{recovery_ns}}}"
            ),
        }
    }
}

/// Write the buffered events as JSONL (one object per line).
pub fn write_jsonl(path: &std::path::Path, events: &[TraceEvent]) -> std::io::Result<()> {
    let mut body = String::with_capacity(events.len() * 96);
    for e in events {
        body.push_str(&e.to_json());
        body.push('\n');
    }
    std::fs::write(path, body)
}
