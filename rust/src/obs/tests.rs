//! Unit tests for the observability plane: histogram bucket boundaries,
//! exact cross-entity merging, zero-filled series, the span lifecycle and
//! the sampling contract.

use super::*;

// ---- histogram -----------------------------------------------------------

#[test]
fn bucket_boundaries_are_powers_of_two() {
    // Bucket 0 is the exact-zero bucket; bucket i covers [2^(i-1), 2^i).
    assert_eq!(LatencyHistogram::bucket_of(0), 0);
    assert_eq!(LatencyHistogram::bucket_of(1), 1);
    assert_eq!(LatencyHistogram::bucket_of(2), 2);
    assert_eq!(LatencyHistogram::bucket_of(3), 2);
    assert_eq!(LatencyHistogram::bucket_of(4), 3);
    assert_eq!(LatencyHistogram::bucket_of(1023), 10);
    assert_eq!(LatencyHistogram::bucket_of(1024), 11);
    assert_eq!(LatencyHistogram::bucket_of(u64::MAX), BUCKETS - 1);
    // Upper bounds are inclusive and one-less-than-a-power-of-two.
    assert_eq!(LatencyHistogram::bucket_upper(0), 0);
    assert_eq!(LatencyHistogram::bucket_upper(1), 1);
    assert_eq!(LatencyHistogram::bucket_upper(11), 2047);
    assert_eq!(LatencyHistogram::bucket_upper(BUCKETS - 1), u64::MAX);
}

#[test]
fn percentiles_report_the_bucket_upper_bound() {
    let mut h = LatencyHistogram::new();
    assert_eq!(h.percentile(50.0), 0, "empty histogram reports 0");
    for v in [100u64, 100, 100, 100, 100, 100, 100, 100, 100, 4000] {
        h.record(v);
    }
    assert_eq!(h.count(), 10);
    // 100 lives in [64, 128) -> upper bound 127; 4000 in [2048, 4096).
    assert_eq!(h.percentile(50.0), 127);
    assert_eq!(h.percentile(0.0), 127);
    assert_eq!(h.percentile(100.0), 4095);
    // The p99 nearest rank of 10 samples is the last one.
    assert_eq!(h.percentile(99.0), 4095);
}

#[test]
fn merge_is_exact_bucketwise_addition() {
    // Per-entity histograms merged must equal one histogram fed everything.
    let mut a = LatencyHistogram::new();
    let mut b = LatencyHistogram::new();
    let mut whole = LatencyHistogram::new();
    for v in [1u64, 50, 999, 12_345] {
        a.record(v);
        whole.record(v);
    }
    for v in [7u64, 7, 1_000_000] {
        b.record(v);
        whole.record(v);
    }
    a.merge(&b);
    assert_eq!(a.count(), whole.count());
    for pct in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
        assert_eq!(a.percentile(pct), whole.percentile(pct), "pct {pct}");
    }
}

// ---- per-second series ---------------------------------------------------

#[test]
fn controller_series_zero_fill_empty_seconds() {
    let mut t = Tracer::default();
    t.configure(1000, "");
    t.note_empty_poll(0);
    t.note_empty_poll(3 * SECOND + 1);
    t.note_empty_poll(3 * SECOND + 2);
    // Seconds 1, 2 and 4 saw nothing: they must read as explicit zeros.
    assert_eq!(t.empty_polls_per_s(5), vec![1, 0, 0, 2, 0]);
    t.note_append_latency(SECOND, 1_000);
    t.note_append_latency(SECOND, 3_000);
    assert_eq!(t.append_latency_per_s(3), vec![0, 2_000, 0]);
    assert_eq!(t.credit_stalls_per_s(2), vec![0, 0]);
}

// ---- span lifecycle ------------------------------------------------------

#[test]
fn span_walks_every_stage_through_the_marker_fifo() {
    let mut t = Tracer::default();
    t.configure(1000, "");
    let produced = t.sample_produced(100).expect("permille=1000 samples everything");
    t.on_append(2, 7, produced, 600); // Append = 500
    t.on_notify(2, 7, 1_600); // Deliver = 1000
    t.on_handoff(Some((2, 7)), 0, 4, 3_600); // Consume = 2000
    t.on_emit(0, 4, 7_600); // Operate = 4000, EndToEnd = 7500
    let r = t.report();
    assert_eq!(r.spans_completed, 1);
    assert_eq!(r.spans_dropped, 0);
    for (stage, upper) in [
        (Stage::Append, 511),   // 500 in [256, 512)
        (Stage::Deliver, 1023), // 1000 in [512, 1024)
        (Stage::Consume, 2047),
        (Stage::Operate, 4095),
        (Stage::EndToEnd, 8191), // 7500 in [4096, 8192)
    ] {
        let s = r.stage(stage).unwrap_or_else(|| panic!("{} recorded", stage.name()));
        assert_eq!(s.count, 1, "{}", stage.name());
        assert_eq!(s.p50_ns, upper, "{}", stage.name());
    }
    // The span event carries all five timestamps.
    assert_eq!(t.events().len(), 1);
    let json = t.events()[0].to_json();
    for needle in [
        "\"type\":\"span\"",
        "\"partition\":2",
        "\"offset\":7",
        "\"produced\":100",
        "\"appended\":600",
        "\"notified\":1600",
        "\"handoff\":3600",
        "\"emitted\":7600",
    ] {
        assert!(json.contains(needle), "{json} lacks {needle}");
    }
}

#[test]
fn unsampled_markers_keep_the_fifo_aligned() {
    // Channel order: unsampled, sampled, unsampled. The operator pops one
    // marker per batch; the sampled span must land on the middle pop.
    let mut t = Tracer::default();
    t.configure(1000, "");
    t.on_append(0, 1, 0, 10);
    t.on_notify(0, 1, 20);
    t.on_handoff(None, 0, 4, 30);
    t.on_handoff(Some((0, 1)), 0, 4, 30);
    t.on_handoff(None, 0, 4, 30);
    t.on_emit(0, 4, 40);
    assert_eq!(t.report().spans_completed, 0, "first pop is the unsampled marker");
    t.on_emit(0, 4, 50);
    assert_eq!(t.report().spans_completed, 1, "second pop completes the span");
    t.on_emit(0, 4, 60);
    assert_eq!(t.report().spans_completed, 1);
    // A pop on a channel that never queued markers is a no-op.
    t.on_emit(9, 4, 70);
    assert_eq!(t.report().spans_completed, 1);
}

#[test]
fn native_finalize_closes_with_zero_operate() {
    let mut t = Tracer::default();
    t.configure(1000, "");
    t.on_append(1, 0, 0, 100);
    t.on_notify(1, 0, 200);
    t.finalize_at_source(1, 0, 3, 300);
    let r = t.report();
    assert_eq!(r.spans_completed, 1);
    assert_eq!(r.stage(Stage::Operate).unwrap().p50_ns, 0, "zero lands in bucket 0");
    assert!(r.stage(Stage::EndToEnd).unwrap().p50_ns >= 300 - 1);
}

#[test]
fn sampling_permille_is_deterministic_and_proportional() {
    let mut t = Tracer::default();
    t.configure(250, "");
    let picks: Vec<bool> = (0..4000).map(|i| t.sample_produced(i).is_some()).collect();
    assert_eq!(picks.iter().filter(|&&p| p).count(), 1000, "250/1000 of 4000");
    // Same config, same call order -> identical decisions.
    let mut t2 = Tracer::default();
    t2.configure(250, "");
    let picks2: Vec<bool> = (0..4000).map(|i| t2.sample_produced(i).is_some()).collect();
    assert_eq!(picks, picks2);
}

#[test]
fn disabled_tracer_is_inert() {
    let mut t = Tracer::default();
    t.configure(0, "");
    assert!(!t.enabled());
    assert!(t.sample_produced(123).is_none());
    assert!(t.gauges(10).is_empty());
    assert!(t.report().stages.is_empty());
    assert!(t.events().is_empty());
}

#[test]
fn fault_drops_in_flight_spans_without_misjoining() {
    let mut t = Tracer::default();
    t.configure(1000, "");
    t.on_append(0, 0, 0, 10);
    t.on_notify(0, 0, 20);
    t.on_handoff(Some((0, 0)), 0, 4, 30);
    t.on_append(0, 1, 0, 40); // still in `opened`
    t.note_fault("worker", 50);
    // Both spans are gone; a later pop finds an empty FIFO.
    t.on_emit(0, 4, 60);
    let r = t.report();
    assert_eq!(r.spans_completed, 0);
    assert_eq!(r.spans_dropped, 2);
    // Replayed chunks re-notify without a span: a clean no-op.
    t.on_notify(0, 0, 70);
    assert_eq!(t.report().spans_completed, 0);
}

#[test]
fn event_json_is_one_object_per_line() {
    let mut t = Tracer::default();
    t.configure(0, "/dev/null"); // events_on via sink path, tracing off
    assert!(t.events_on());
    t.note_epoch(3, 1_000, 500);
    t.note_switch(2, true, 2_000);
    t.note_fault("source", 3_000);
    t.note_restore(4_000, 900);
    let lines: Vec<String> = t.events().iter().map(|e| e.to_json()).collect();
    assert_eq!(lines.len(), 4);
    assert!(lines[0].contains("\"type\":\"epoch\"") && lines[0].contains("\"epoch\":3"));
    assert!(lines[1].contains("\"to\":\"push\""));
    assert!(lines[2].contains("\"kind\":\"source\""));
    assert!(lines[3].contains("\"recovery_ns\":900"));
    for l in &lines {
        assert!(l.starts_with('{') && l.ends_with('}') && !l.contains('\n'));
    }
}
