//! Observability: per-record latency spans, log2 histograms, controller
//! gauges, and the JSONL trace/event sink.
//!
//! The paper's headline claim is that the push-based source *reduces
//! processing latency*; the figure harnesses only ever measured p50
//! throughput. This module closes that gap: it traces a sampled subset of
//! records through their whole life across the zero-copy spine and folds
//! each stage delta into fixed-footprint log2 histograms ([`hist`]),
//! giving every experiment per-stage p50/p95/p99/p999 — and gives the
//! ROADMAP's elastic-runtime direction the controller inputs it needs
//! (queue depths, credit-starvation and empty-poll rates, append-latency
//! time series).
//!
//! ## Span lifecycle
//!
//! A span is five timestamps riding *beside* the `Chunk`/`Batch` spine
//! (never inside it — `Msg` has a 64-byte budget the data variants fill):
//!
//! ```text
//! produced ── Append ──> appended ── Deliver ──> notified
//!          ── Consume ──> handoff ── Operate ──> emitted
//! ```
//!
//! * **produced**: the writer stamps its request at staging time and sends
//!   the timestamp in the (boxed, budget-free) `Append`/`SealObject` RPC
//!   envelope.
//! * **appended**: the broker finishes the append — after dispatch, queue
//!   and worker-phase service, so the `Append` stage delta includes the
//!   durable store's WAL cost. The broker keys the span by
//!   `(partition, chunk offset)`, the identity the spine already carries.
//! * **notified**: the source first observes the chunk's offsets — the
//!   pull reply (`on_reply`), the push object consume, or the native
//!   reply. The `Deliver` stage is the storage→source hand-off the paper
//!   argues about.
//! * **handoff**: the source emits the chunk's batch into the pipeline.
//!   Offsets are gone from `Batch`, so the tracer bridges the hop with a
//!   per-channel marker FIFO (below).
//! * **emitted**: the first operator task finishes processing the batch;
//!   `Operate` is queue wait + operator service, and `EndToEnd` closes
//!   produced → emitted. Engine-less sources (native) emit at the source,
//!   with a zero `Operate` stage.
//!
//! ## The marker-FIFO bridge
//!
//! `Batch` is exactly at its size budget, so spans cannot ride it across
//! the source→operator hop. Instead the tracer exploits a DES invariant:
//! delivery on one (sender, receiver) channel is FIFO (same constant
//! queue-hop latency, deterministic tie order). While tracing is enabled,
//! a source pushes one marker per batch it sends on a channel —
//! `Some(span)` for sampled batches, `None` otherwise — and the operator
//! pops one marker per batch it processes from that channel. Order
//! matches exactly; a fault/rollback clears the in-flight markers (the
//! dropped spans are counted, never mis-joined, and replayed chunks
//! re-enter cleanly because their spans were already retired).
//!
//! ## Sampling contract
//!
//! `trace_sample_permille` picks spans deterministically (a shared
//! counter, `counter % 1000 < permille` — the DES makes this
//! reproducible): 1000 traces every request, 0 turns the plane **off
//! completely**. Off means off: writers, sources and operators gate every
//! tracer call on [`Tracer::enabled`], the RPC field stays `None`, no
//! histogram, FIFO or event buffer is ever touched — the zero-copy parity
//! suite pins that a traced-off run is byte-identical (same totals, same
//! `proto::real_payload_allocs`) to one that never knew about tracing.
//!
//! Histograms are kept per (stage, entity) and merged exactly across
//! entities at report time ([`LatencyReport`]); the per-virtual-second
//! dimension lives in the controller-input series (empty polls, credit
//! stalls, append latency), which zero-fill idle seconds like the
//! metrics hub.

mod hist;
mod sink;
#[cfg(test)]
mod tests;

use std::collections::{HashMap, VecDeque};
use std::sync::OnceLock;
use std::time::Instant;

pub use hist::{LatencyHistogram, LatencyReport, StageStat, BUCKETS};
pub use sink::{write_jsonl, TraceEvent};

use crate::sim::{Time, SECOND};

/// Process-wide epoch for wall-clock tracing on the real plane. One
/// `Instant` shared by every node thread's tracer, so a producer-node
/// `produced_at` stamp and the colo node's stage closes live on the same
/// axis — each node's *engine* clock is private to its thread and not
/// comparable across the TCP boundary.
static WALL_EPOCH: OnceLock<Instant> = OnceLock::new();

/// A span stage — one hop of the produce → emit life.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// produced → appended: writer staging to broker log append (includes
    /// the RPC/seal path and the durable store's WAL cost).
    Append,
    /// appended → notified: log append to the source observing the chunk
    /// (pull reply / push consume) — the paper's contested hop.
    Deliver,
    /// notified → handoff: source-side processing until the batch enters
    /// the pipeline.
    Consume,
    /// handoff → emitted: queue wait + first operator service.
    Operate,
    /// produced → emitted.
    EndToEnd,
}

impl Stage {
    pub const ALL: [Stage; 5] =
        [Stage::Append, Stage::Deliver, Stage::Consume, Stage::Operate, Stage::EndToEnd];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Append => "append",
            Stage::Deliver => "deliver",
            Stage::Consume => "consume",
            Stage::Operate => "operate",
            Stage::EndToEnd => "end_to_end",
        }
    }
}

/// Span state after the broker append.
#[derive(Debug, Clone, Copy)]
struct Opened {
    produced: Time,
    appended: Time,
}

/// Span state after the source observed the chunk.
#[derive(Debug, Clone, Copy)]
struct Notified {
    produced: Time,
    appended: Time,
    notified: Time,
}

/// Span state travelling the marker FIFO into the pipeline.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    partition: u64,
    offset: u64,
    source: usize,
    produced: Time,
    appended: Time,
    notified: Time,
    handoff: Time,
}

/// The tracing plane. One instance lives inside the [`crate::metrics::MetricsHub`]
/// blackboard every actor already holds, so no actor needed rewiring.
#[derive(Debug, Default)]
pub struct Tracer {
    permille: u32,
    out: String,
    /// Wall-clock mode (real plane): every `now` argument is replaced
    /// with nanoseconds since [`WALL_EPOCH`] at method entry, so span
    /// deltas measure real elapsed time instead of a node-local engine
    /// clock. Off (the default) on the sim plane, where the virtual
    /// clock is the ground truth.
    wall_clock: bool,
    sample_counter: u64,
    /// Spans between append and source notify, keyed (partition, offset).
    opened: HashMap<(usize, u64), Opened>,
    /// Spans between notify and pipeline hand-off, keyed (partition, offset).
    notified: HashMap<(usize, u64), Notified>,
    /// Marker FIFOs keyed (from_task, to_task): one entry per batch sent on
    /// the channel while tracing, `Some` only for sampled batches.
    handoff: HashMap<(usize, usize), VecDeque<Option<InFlight>>>,
    /// Per-(stage, entity) histograms; merged exactly at report time.
    hists: HashMap<(Stage, usize), LatencyHistogram>,
    events: Vec<TraceEvent>,
    // Controller-input series (ROADMAP item 4), per virtual second.
    empty_polls: Vec<u64>,
    credit_stalls: Vec<u64>,
    append_ns_sum: Vec<u64>,
    append_acks: Vec<u64>,
    spans_completed: u64,
    spans_dropped: u64,
}

fn bump(series: &mut Vec<u64>, now: Time, n: u64) {
    let sec = (now / SECOND) as usize;
    if series.len() <= sec {
        series.resize(sec + 1, 0);
    }
    series[sec] += n;
}

impl Tracer {
    /// Install the run's knobs. Called once by the launcher before any
    /// actor is built.
    pub fn configure(&mut self, permille: u32, out: &str) {
        self.permille = permille.min(1000);
        self.out = out.to_string();
    }

    /// Switch this tracer to wall-clock timestamps (real plane). Called by
    /// each node thread before its actors are built; the first caller
    /// pins the process-wide epoch.
    pub fn set_wall_clock(&mut self) {
        WALL_EPOCH.get_or_init(Instant::now);
        self.wall_clock = true;
    }

    /// The timestamp every public method actually records: the caller's
    /// engine clock on the sim plane, nanoseconds since the shared epoch
    /// in wall-clock mode.
    fn clock(&self, now: Time) -> Time {
        if self.wall_clock {
            WALL_EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as Time
        } else {
            now
        }
    }

    /// The hot-path gate: every caller checks this before touching the
    /// tracer. `false` means the whole plane is inert.
    pub fn enabled(&self) -> bool {
        self.permille > 0
    }

    /// Whether rare events (epochs, switches, faults) should be buffered:
    /// tracing is on, or a sink path wants the event stream.
    pub fn events_on(&self) -> bool {
        self.permille > 0 || !self.out.is_empty()
    }

    // ---- span lifecycle ---------------------------------------------------

    /// Writer staging: deterministically decide whether this request is
    /// sampled; `Some(now)` becomes the RPC's `produced_at`.
    pub fn sample_produced(&mut self, now: Time) -> Option<Time> {
        if self.permille == 0 {
            return None;
        }
        let pick = self.sample_counter % 1000 < self.permille as u64;
        self.sample_counter += 1;
        pick.then_some(self.clock(now))
    }

    /// Broker log append of a sampled chunk: open the span. `produced`
    /// came through the RPC from the writer's tracer and is already on
    /// the right clock.
    pub fn on_append(&mut self, partition: usize, offset: u64, produced: Time, now: Time) {
        let now = self.clock(now);
        self.hist(Stage::Append, partition).record(now.saturating_sub(produced));
        self.opened.insert((partition, offset), Opened { produced, appended: now });
    }

    /// Source observed the chunk (pull reply / push consume): close the
    /// Deliver stage. No-op for unsampled or already-retired chunks (e.g.
    /// replay after a fault).
    pub fn on_notify(&mut self, partition: usize, offset: u64, now: Time) {
        let now = self.clock(now);
        if let Some(o) = self.opened.remove(&(partition, offset)) {
            self.hist(Stage::Deliver, partition).record(now.saturating_sub(o.appended));
            self.notified.insert(
                (partition, offset),
                Notified { produced: o.produced, appended: o.appended, notified: now },
            );
        }
    }

    /// Source sends one batch on channel (from → to). Call once **per
    /// batch sent** while tracing; `key` is the chunk identity for sampled
    /// batches, `None` otherwise. Closes Consume and queues the marker.
    pub fn on_handoff(
        &mut self,
        key: Option<(usize, u64)>,
        from: usize,
        to: usize,
        now: Time,
    ) {
        let now = self.clock(now);
        let mut marker = None;
        if let Some((partition, offset)) = key {
            if let Some(n) = self.notified.remove(&(partition, offset)) {
                self.hist(Stage::Consume, from).record(now.saturating_sub(n.notified));
                marker = Some(InFlight {
                    partition: partition as u64,
                    offset,
                    source: from,
                    produced: n.produced,
                    appended: n.appended,
                    notified: n.notified,
                    handoff: now,
                });
            }
        }
        self.handoff.entry((from, to)).or_default().push_back(marker);
    }

    /// Operator task finished one batch from channel (from → to). Call
    /// once **per batch processed** while tracing; closes Operate and
    /// EndToEnd for sampled batches.
    pub fn on_emit(&mut self, from: usize, to: usize, now: Time) {
        let now = self.clock(now);
        let Some(fifo) = self.handoff.get_mut(&(from, to)) else { return };
        let Some(marker) = fifo.pop_front() else { return };
        if let Some(s) = marker {
            self.hist(Stage::Operate, to).record(now.saturating_sub(s.handoff));
            self.hist(Stage::EndToEnd, to).record(now.saturating_sub(s.produced));
            self.spans_completed += 1;
            if self.events_on() {
                self.events.push(TraceEvent::Span {
                    partition: s.partition,
                    offset: s.offset,
                    source: s.source,
                    task: to,
                    produced: s.produced,
                    appended: s.appended,
                    notified: s.notified,
                    handoff: s.handoff,
                    emitted: now,
                });
            }
        }
    }

    /// Engine-less finalisation (the native source has no pipeline):
    /// Consume closes at `now`, Operate is zero, EndToEnd closes.
    pub fn finalize_at_source(&mut self, partition: usize, offset: u64, source: usize, now: Time) {
        let now = self.clock(now);
        if let Some(n) = self.notified.remove(&(partition, offset)) {
            self.hist(Stage::Consume, source).record(now.saturating_sub(n.notified));
            self.hist(Stage::Operate, source).record(0);
            self.hist(Stage::EndToEnd, source).record(now.saturating_sub(n.produced));
            self.spans_completed += 1;
            if self.events_on() {
                self.events.push(TraceEvent::Span {
                    partition: partition as u64,
                    offset,
                    source,
                    task: source,
                    produced: n.produced,
                    appended: n.appended,
                    notified: n.notified,
                    handoff: now,
                    emitted: now,
                });
            }
        }
    }

    fn hist(&mut self, stage: Stage, entity: usize) -> &mut LatencyHistogram {
        self.hists.entry((stage, entity)).or_default()
    }

    // ---- controller-input series -----------------------------------------

    /// A pull/native poll returned no data.
    pub fn note_empty_poll(&mut self, now: Time) {
        let now = self.clock(now);
        bump(&mut self.empty_polls, now, 1);
    }

    /// A source exhausted its downstream credits and blocked.
    pub fn note_credit_stall(&mut self, now: Time) {
        let now = self.clock(now);
        bump(&mut self.credit_stalls, now, 1);
    }

    /// A writer's append round-trip completed (ack received).
    pub fn note_append_latency(&mut self, now: Time, rtt_ns: u64) {
        let now = self.clock(now);
        bump(&mut self.append_ns_sum, now, rtt_ns);
        bump(&mut self.append_acks, now, 1);
    }

    /// Append-latency time series: mean RTT (ns) per virtual second over
    /// `[0, horizon_s)`, zero-filled like the metrics hub's series.
    pub fn append_latency_per_s(&self, horizon_s: u64) -> Vec<u64> {
        (0..horizon_s as usize)
            .map(|s| {
                let acks = self.append_acks.get(s).copied().unwrap_or(0);
                if acks == 0 {
                    0
                } else {
                    self.append_ns_sum.get(s).copied().unwrap_or(0) / acks
                }
            })
            .collect()
    }

    /// A per-second series, zero-filled to the horizon.
    pub fn series_per_s(series: &[u64], horizon_s: u64) -> Vec<u64> {
        (0..horizon_s as usize).map(|s| series.get(s).copied().unwrap_or(0)).collect()
    }

    pub fn empty_polls_per_s(&self, horizon_s: u64) -> Vec<u64> {
        Self::series_per_s(&self.empty_polls, horizon_s)
    }

    pub fn credit_stalls_per_s(&self, horizon_s: u64) -> Vec<u64> {
        Self::series_per_s(&self.credit_stalls, horizon_s)
    }

    // ---- rare events ------------------------------------------------------

    /// A checkpoint epoch completed.
    pub fn note_epoch(&mut self, epoch: u64, at: Time, span_ns: u64) {
        if self.events_on() {
            let at = self.clock(at);
            self.events.push(TraceEvent::Epoch { epoch, at, span_ns });
        }
    }

    /// The hybrid source switched mechanisms.
    pub fn note_switch(&mut self, task: usize, to_push: bool, at: Time) {
        if self.events_on() {
            let at = self.clock(at);
            self.events.push(TraceEvent::Switch { task, to_push, at });
        }
    }

    /// Fault injection fired: drop all in-flight span state — the channel
    /// FIFOs are about to be rebuilt by replay, and a mis-joined marker
    /// would be worse than a dropped span.
    pub fn note_fault(&mut self, kind: &'static str, at: Time) {
        if self.events_on() {
            let at = self.clock(at);
            self.events.push(TraceEvent::Fault { kind, at });
        }
        self.drop_in_flight();
    }

    /// Recovery completed.
    pub fn note_restore(&mut self, at: Time, recovery_ns: u64) {
        if self.events_on() {
            let at = self.clock(at);
            self.events.push(TraceEvent::Restore { at, recovery_ns });
        }
    }

    fn drop_in_flight(&mut self) {
        self.spans_dropped += self.opened.len() as u64 + self.notified.len() as u64;
        self.opened.clear();
        self.notified.clear();
        for fifo in self.handoff.values_mut() {
            self.spans_dropped += fifo.iter().filter(|m| m.is_some()).count() as u64;
            fifo.clear();
        }
    }

    // ---- end-of-run reporting --------------------------------------------

    /// Merge the per-entity histograms into one [`StageStat`] per stage.
    pub fn report(&self) -> LatencyReport {
        let mut stages = Vec::new();
        for &stage in &Stage::ALL {
            let mut merged = LatencyHistogram::new();
            for ((s, _), h) in &self.hists {
                if *s == stage {
                    merged.merge(h);
                }
            }
            if !merged.is_empty() {
                stages.push(StageStat::from_hist(stage, &merged));
            }
        }
        let in_flight = self.opened.len() as u64
            + self.notified.len() as u64
            + self
                .handoff
                .values()
                .map(|f| f.iter().filter(|m| m.is_some()).count() as u64)
                .sum::<u64>();
        LatencyReport {
            stages,
            spans_completed: self.spans_completed,
            spans_dropped: self.spans_dropped + in_flight,
        }
    }

    /// The controller-input gauges the launcher exports at finish.
    pub fn gauges(&self, horizon_s: u64) -> Vec<(String, f64)> {
        if !self.enabled() {
            return Vec::new();
        }
        let mean = |s: &[u64]| {
            if horizon_s == 0 {
                0.0
            } else {
                s.iter().take(horizon_s as usize).sum::<u64>() as f64 / horizon_s as f64
            }
        };
        let report = self.report();
        let mut g = vec![
            ("obs.spans_completed".to_string(), self.spans_completed as f64),
            ("obs.spans_dropped".to_string(), report.spans_dropped as f64),
            ("obs.empty_polls_per_s".to_string(), mean(&self.empty_polls)),
            ("obs.credit_stalls_per_s".to_string(), mean(&self.credit_stalls)),
            (
                "obs.append_latency_us_mean".to_string(),
                mean(&self.append_latency_per_s(horizon_s)) / 1e3,
            ),
        ];
        for st in &report.stages {
            g.push((format!("obs.{}_p50_us", st.stage.name()), st.p50_ns as f64 / 1e3));
            g.push((format!("obs.{}_p99_us", st.stage.name()), st.p99_ns as f64 / 1e3));
        }
        g
    }

    /// Buffered events, in DES order (the JSONL sink's content).
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Flush the event buffer to `trace_out` as JSONL; `Ok(None)` when no
    /// sink path is configured.
    pub fn write_sink(&self) -> std::io::Result<Option<String>> {
        if self.out.is_empty() {
            return Ok(None);
        }
        let path = std::path::PathBuf::from(&self.out);
        write_jsonl(&path, &self.events)?;
        Ok(Some(self.out.clone()))
    }
}
