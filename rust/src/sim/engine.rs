//! Event queue, actor registry and the run loop.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::{Rng, Time};

/// Index of a registered actor. Stable for the lifetime of the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ActorId(pub usize);

impl std::fmt::Display for ActorId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "actor#{}", self.0)
    }
}

/// A component of the simulated cluster, driven purely by messages.
pub trait Actor<M> {
    /// Handle one message delivered at virtual time `ctx.now()`.
    fn on_event(&mut self, msg: M, ctx: &mut Ctx<'_, M>);

    /// Called once when the engine starts, before any event — the place to
    /// schedule the actor's first self-message (timers, first RPC, ...).
    fn on_start(&mut self, _ctx: &mut Ctx<'_, M>) {}

    /// Human-readable label for traces and panics.
    fn label(&self) -> String {
        "actor".to_string()
    }

    /// Downcast hook so the launcher can inspect an actor after the run
    /// (export gauges, read end-of-run state). Return `Some(self)` to
    /// opt in.
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

struct Scheduled<M> {
    time: Time,
    seq: u64,
    target: ActorId,
    msg: M,
}

// Order by (time, seq): deterministic FIFO among equal timestamps.
impl<M> PartialEq for Scheduled<M> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<M> Eq for Scheduled<M> {}
impl<M> PartialOrd for Scheduled<M> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<M> Ord for Scheduled<M> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Handle actors use to read the clock, schedule messages and draw
/// deterministic randomness. Emissions are buffered and flushed into the
/// event queue after the handler returns (so a handler never observes its
/// own sends).
pub struct Ctx<'a, M> {
    now: Time,
    self_id: ActorId,
    emits: &'a mut Vec<(Time, ActorId, M)>,
    rng: &'a mut Rng,
    stop: &'a mut bool,
}

impl<'a, M> Ctx<'a, M> {
    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// The actor this event was delivered to.
    pub fn self_id(&self) -> ActorId {
        self.self_id
    }

    /// Deliver `msg` to `target` at absolute virtual time `at`
    /// (clamped to now — scheduling in the past is a bug we surface loudly).
    pub fn send_at(&mut self, at: Time, target: ActorId, msg: M) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.emits.push((at.max(self.now), target, msg));
    }

    /// Deliver `msg` to `target` after `delay`.
    pub fn send_in(&mut self, delay: Time, target: ActorId, msg: M) {
        self.emits.push((self.now + delay, target, msg));
    }

    /// Deliver `msg` to `target` "now" (ordered after already-queued events
    /// at this timestamp).
    pub fn send(&mut self, target: ActorId, msg: M) {
        self.send_in(0, target, msg);
    }

    /// Self-message after `delay` — the idiom for timers and thread loops.
    pub fn send_self_in(&mut self, delay: Time, msg: M) {
        let id = self.self_id;
        self.send_in(delay, id, msg);
    }

    /// Deterministic per-engine RNG.
    pub fn rng(&mut self) -> &mut Rng {
        self.rng
    }

    /// Ask the engine to stop after this handler returns.
    pub fn stop(&mut self) {
        *self.stop = true;
    }
}

/// The simulation: actor registry + event queue + virtual clock.
pub struct Engine<M> {
    clock: Time,
    seq: u64,
    queue: BinaryHeap<Reverse<Scheduled<M>>>,
    actors: Vec<Box<dyn Actor<M>>>,
    events_processed: u64,
    started: bool,
    rng: Rng,
}

impl<M> Engine<M> {
    pub fn new(seed: u64) -> Self {
        Self {
            clock: 0,
            seq: 0,
            queue: BinaryHeap::new(),
            actors: Vec::new(),
            events_processed: 0,
            started: false,
            rng: Rng::new(seed),
        }
    }

    /// Register an actor; its id is fixed from now on.
    pub fn add_actor(&mut self, actor: Box<dyn Actor<M>>) -> ActorId {
        self.actors.push(actor);
        ActorId(self.actors.len() - 1)
    }

    /// Number of registered actors.
    pub fn actor_count(&self) -> usize {
        self.actors.len()
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.clock
    }

    /// Total events processed so far (engine throughput metric).
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Schedule an external (bootstrap) message.
    pub fn schedule(&mut self, at: Time, target: ActorId, msg: M) {
        assert!(target.0 < self.actors.len(), "unknown {target}");
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Reverse(Scheduled { time: at.max(self.clock), seq, target, msg }));
    }

    fn flush_emits(&mut self, emits: &mut Vec<(Time, ActorId, M)>) {
        for (time, target, msg) in emits.drain(..) {
            assert!(
                target.0 < self.actors.len(),
                "send to unregistered {target} at t={time}"
            );
            let seq = self.seq;
            self.seq += 1;
            self.queue.push(Reverse(Scheduled { time, seq, target, msg }));
        }
    }

    fn start(&mut self) {
        let mut emits = Vec::new();
        let mut stop = false;
        for i in 0..self.actors.len() {
            let mut actor = std::mem::replace(&mut self.actors[i], Box::new(Nop));
            {
                let mut ctx = Ctx {
                    now: self.clock,
                    self_id: ActorId(i),
                    emits: &mut emits,
                    rng: &mut self.rng,
                    stop: &mut stop,
                };
                actor.on_start(&mut ctx);
            }
            self.actors[i] = actor;
        }
        self.flush_emits(&mut emits);
        self.started = true;
    }

    /// Run until the queue drains or virtual time would pass `until`.
    /// Returns the number of events processed by this call.
    pub fn run_until(&mut self, until: Time) -> u64 {
        if !self.started {
            self.start();
        }
        let mut emits: Vec<(Time, ActorId, M)> = Vec::new();
        let mut processed = 0;
        let mut stop = false;
        while let Some(Reverse(head)) = self.queue.peek() {
            if head.time > until {
                break;
            }
            let Reverse(ev) = self.queue.pop().expect("peeked");
            debug_assert!(ev.time >= self.clock, "time went backwards");
            self.clock = ev.time;
            // Temporarily take the actor out so it can freely use Ctx while
            // the engine remains borrowable for the emit buffer.
            let mut actor = std::mem::replace(&mut self.actors[ev.target.0], Box::new(Nop));
            {
                let mut ctx = Ctx {
                    now: self.clock,
                    self_id: ev.target,
                    emits: &mut emits,
                    rng: &mut self.rng,
                    stop: &mut stop,
                };
                actor.on_event(ev.msg, &mut ctx);
            }
            self.actors[ev.target.0] = actor;
            self.flush_emits(&mut emits);
            processed += 1;
            self.events_processed += 1;
            if stop {
                break;
            }
        }
        // Advance the clock to the horizon even if we idled out early.
        if self.clock < until && self.queue.iter().all(|Reverse(s)| s.time > until) {
            self.clock = until;
        }
        processed
    }

    /// Run to quiescence (empty queue). Use only for bounded workloads.
    pub fn run_to_quiescence(&mut self) -> u64 {
        self.run_until(Time::MAX)
    }

    /// Borrow an actor downcast to its concrete type (see
    /// [`Actor::as_any_mut`]); `None` if the id is unknown, the actor does
    /// not opt in, or the type does not match.
    pub fn actor_as<T: 'static>(&mut self, id: ActorId) -> Option<&mut T> {
        self.actors
            .get_mut(id.0)?
            .as_any_mut()?
            .downcast_mut::<T>()
    }
}

/// Placeholder actor swapped in while a real actor's handler runs.
struct Nop;
impl<M> Actor<M> for Nop {
    fn on_event(&mut self, _msg: M, _ctx: &mut Ctx<'_, M>) {
        panic!("message delivered to an actor that is currently executing (re-entrancy)");
    }
}
