//! Event queue, actor registry and the run loop.
//!
//! ## Hot-path layout
//!
//! The queue is two-level (perf pass, EXPERIMENTS.md §Perf):
//!
//! * a FIFO **now-queue** for events scheduled at the *current* timestamp
//!   — credit returns, store notifications and every other `send`-with-
//!   zero-delay, which dominate a busy cluster. They enqueue and dequeue
//!   in O(1) and never touch the heap;
//! * the binary **heap** for everything in the future.
//!
//! The total delivery order is identical to a single heap ordered by
//! `(time, seq)`: now-queue entries carry their timestamp and globally
//! monotone sequence numbers, the clock never goes backwards, so the
//! now-queue is always FIFO-sorted by `(time, seq)` and a two-way front
//! comparison picks the global minimum. Determinism is bit-for-bit
//! unchanged (see `sim/tests.rs` and the property tests).
//!
//! The per-event emit buffer is owned by the engine and reused across
//! every dispatch and `run_until` call — a handler's sends go through a
//! pre-grown `Vec` that is drained, never dropped.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use super::{Rng, Time};

/// Index of a registered actor. Stable for the lifetime of the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ActorId(pub usize);

impl std::fmt::Display for ActorId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "actor#{}", self.0)
    }
}

/// A component of the simulated cluster, driven purely by messages.
pub trait Actor<M> {
    /// Handle one message delivered at virtual time `ctx.now()`.
    fn on_event(&mut self, msg: M, ctx: &mut Ctx<'_, M>);

    /// Called once when the engine starts, before any event — the place to
    /// schedule the actor's first self-message (timers, first RPC, ...).
    fn on_start(&mut self, _ctx: &mut Ctx<'_, M>) {}

    /// Human-readable label for traces and panics.
    fn label(&self) -> String {
        "actor".to_string()
    }

    /// Downcast hook so the launcher can inspect an actor after the run
    /// (export gauges, read end-of-run state). Return `Some(self)` to
    /// opt in.
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

struct Scheduled<M> {
    time: Time,
    seq: u64,
    target: ActorId,
    msg: M,
}

// Order by (time, seq): deterministic FIFO among equal timestamps.
impl<M> PartialEq for Scheduled<M> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<M> Eq for Scheduled<M> {}
impl<M> PartialOrd for Scheduled<M> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<M> Ord for Scheduled<M> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Handle actors use to read the clock, schedule messages and draw
/// deterministic randomness. Emissions are buffered and flushed into the
/// event queue after the handler returns (so a handler never observes its
/// own sends).
pub struct Ctx<'a, M> {
    now: Time,
    self_id: ActorId,
    emits: &'a mut Vec<(Time, ActorId, M)>,
    rng: &'a mut Rng,
    stop: &'a mut bool,
}

impl<'a, M> Ctx<'a, M> {
    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// The actor this event was delivered to.
    pub fn self_id(&self) -> ActorId {
        self.self_id
    }

    /// Deliver `msg` to `target` at absolute virtual time `at`
    /// (clamped to now — scheduling in the past is a bug we surface loudly).
    pub fn send_at(&mut self, at: Time, target: ActorId, msg: M) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.emits.push((at.max(self.now), target, msg));
    }

    /// Deliver `msg` to `target` after `delay`.
    pub fn send_in(&mut self, delay: Time, target: ActorId, msg: M) {
        self.emits.push((self.now + delay, target, msg));
    }

    /// Deliver `msg` to `target` "now" (ordered after already-queued events
    /// at this timestamp). These are the events the engine's now-queue
    /// serves without touching the heap.
    pub fn send(&mut self, target: ActorId, msg: M) {
        self.send_in(0, target, msg);
    }

    /// Self-message after `delay` — the idiom for timers and thread loops.
    pub fn send_self_in(&mut self, delay: Time, msg: M) {
        let id = self.self_id;
        self.send_in(delay, id, msg);
    }

    /// Deterministic per-engine RNG.
    pub fn rng(&mut self) -> &mut Rng {
        self.rng
    }

    /// Ask the engine to stop after this handler returns.
    pub fn stop(&mut self) {
        *self.stop = true;
    }
}

/// The simulation: actor registry + event queue + virtual clock.
pub struct Engine<M> {
    clock: Time,
    seq: u64,
    /// Future events, ordered by `(time, seq)`.
    queue: BinaryHeap<Reverse<Scheduled<M>>>,
    /// Events at the current timestamp: FIFO == `(time, seq)` order
    /// because seq is globally monotone and the clock never rewinds.
    now_queue: VecDeque<Scheduled<M>>,
    actors: Vec<Box<dyn Actor<M>>>,
    /// The handlers' emit buffer, reused across every dispatch.
    emit_buf: Vec<(Time, ActorId, M)>,
    events_processed: u64,
    started: bool,
    rng: Rng,
}

impl<M> Engine<M> {
    pub fn new(seed: u64) -> Self {
        Self {
            clock: 0,
            seq: 0,
            queue: BinaryHeap::new(),
            now_queue: VecDeque::new(),
            actors: Vec::new(),
            emit_buf: Vec::new(),
            events_processed: 0,
            started: false,
            rng: Rng::new(seed),
        }
    }

    /// Register an actor; its id is fixed from now on.
    pub fn add_actor(&mut self, actor: Box<dyn Actor<M>>) -> ActorId {
        self.actors.push(actor);
        ActorId(self.actors.len() - 1)
    }

    /// Number of registered actors.
    pub fn actor_count(&self) -> usize {
        self.actors.len()
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.clock
    }

    /// Total events processed so far (engine throughput metric).
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Route one event into the right queue: current-timestamp events take
    /// the O(1) FIFO fast path, future events the heap.
    fn push_event(&mut self, time: Time, target: ActorId, msg: M) {
        assert!(
            target.0 < self.actors.len(),
            "send to unregistered {target} at t={time}"
        );
        let seq = self.seq;
        self.seq += 1;
        let ev = Scheduled { time, seq, target, msg };
        if time <= self.clock {
            debug_assert!(time == self.clock, "scheduling into the past");
            self.now_queue.push_back(ev);
        } else {
            self.queue.push(Reverse(ev));
        }
    }

    /// Schedule an external (bootstrap) message.
    pub fn schedule(&mut self, at: Time, target: ActorId, msg: M) {
        self.push_event(at.max(self.clock), target, msg);
    }

    /// Earliest scheduled `(time)` across both queues, if any.
    fn peek_time(&self) -> Option<Time> {
        let now_t = self.now_queue.front().map(|s| s.time);
        let heap_t = self.queue.peek().map(|Reverse(s)| s.time);
        match (now_t, heap_t) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Pop the globally earliest event by `(time, seq)`.
    fn pop_next(&mut self) -> Option<Scheduled<M>> {
        let take_now = match (self.now_queue.front(), self.queue.peek()) {
            (Some(nq), Some(Reverse(h))) => (nq.time, nq.seq) < (h.time, h.seq),
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => return None,
        };
        if take_now {
            self.now_queue.pop_front()
        } else {
            self.queue.pop().map(|Reverse(s)| s)
        }
    }

    fn flush_emits(&mut self, emits: &mut Vec<(Time, ActorId, M)>) {
        for (time, target, msg) in emits.drain(..) {
            self.push_event(time, target, msg);
        }
    }

    fn start(&mut self) {
        let mut emits = std::mem::take(&mut self.emit_buf);
        let mut stop = false;
        for i in 0..self.actors.len() {
            let mut actor = std::mem::replace(&mut self.actors[i], Box::new(Nop));
            {
                let mut ctx = Ctx {
                    now: self.clock,
                    self_id: ActorId(i),
                    emits: &mut emits,
                    rng: &mut self.rng,
                    stop: &mut stop,
                };
                actor.on_start(&mut ctx);
            }
            self.actors[i] = actor;
            self.flush_emits(&mut emits);
        }
        self.emit_buf = emits;
        self.started = true;
    }

    /// Run until the queue drains or virtual time would pass `until`.
    /// Returns the number of events processed by this call.
    pub fn run_until(&mut self, until: Time) -> u64 {
        let processed = self.run_events(until);
        // Advance the clock to the horizon even if we idled out early.
        if self.clock < until
            && self.now_queue.is_empty()
            && self.queue.iter().all(|Reverse(s)| s.time > until)
        {
            self.clock = until;
        }
        processed
    }

    /// Process every queued event and stop with the clock at the LAST
    /// delivered event's time — never saturated to a horizon. This is the
    /// real plane's pump: between socket polls the node drains whatever
    /// its actors have queued, and the virtual clock must stay meaningful
    /// (per-second metric buckets, timer deltas) across an arbitrary
    /// number of pump calls.
    pub fn drain(&mut self) -> u64 {
        self.run_events(Time::MAX)
    }

    fn run_events(&mut self, until: Time) -> u64 {
        if !self.started {
            self.start();
        }
        let mut emits = std::mem::take(&mut self.emit_buf);
        let mut processed = 0;
        let mut stop = false;
        while let Some(t) = self.peek_time() {
            if t > until {
                break;
            }
            let ev = self.pop_next().expect("peeked");
            debug_assert!(ev.time >= self.clock, "time went backwards");
            self.clock = ev.time;
            // Temporarily take the actor out so it can freely use Ctx while
            // the engine remains borrowable for the emit buffer. `Nop` is a
            // ZST, so the placeholder box never allocates.
            let mut actor = std::mem::replace(&mut self.actors[ev.target.0], Box::new(Nop));
            {
                let mut ctx = Ctx {
                    now: self.clock,
                    self_id: ev.target,
                    emits: &mut emits,
                    rng: &mut self.rng,
                    stop: &mut stop,
                };
                actor.on_event(ev.msg, &mut ctx);
            }
            self.actors[ev.target.0] = actor;
            self.flush_emits(&mut emits);
            processed += 1;
            self.events_processed += 1;
            if stop {
                break;
            }
        }
        self.emit_buf = emits;
        processed
    }

    /// Run to quiescence (empty queue). Use only for bounded workloads.
    pub fn run_to_quiescence(&mut self) -> u64 {
        self.run_until(Time::MAX)
    }

    /// Borrow an actor downcast to its concrete type (see
    /// [`Actor::as_any_mut`]); `None` if the id is unknown, the actor does
    /// not opt in, or the type does not match.
    pub fn actor_as<T: 'static>(&mut self, id: ActorId) -> Option<&mut T> {
        self.actors
            .get_mut(id.0)?
            .as_any_mut()?
            .downcast_mut::<T>()
    }
}

/// Placeholder actor swapped in while a real actor's handler runs.
struct Nop;
impl<M> Actor<M> for Nop {
    fn on_event(&mut self, _msg: M, _ctx: &mut Ctx<'_, M>) {
        panic!("message delivered to an actor that is currently executing (re-entrancy)");
    }
}
