//! Unit tests for the DES engine, core pool and RNG.

use std::cell::RefCell;
use std::rc::Rc;

use super::*;

#[derive(Debug, Clone, PartialEq)]
enum TestMsg {
    Ping(u32),
    Tick,
    Fwd(ActorId, u32),
}

/// Records every (time, payload) it receives into a shared log.
struct Recorder {
    log: Rc<RefCell<Vec<(Time, u32)>>>,
}

impl Actor<TestMsg> for Recorder {
    fn on_event(&mut self, msg: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
        if let TestMsg::Ping(v) = msg {
            self.log.borrow_mut().push((ctx.now(), v));
        }
    }
}

/// Sends Ping(i) to a target every `period`, `n` times, starting at t=period.
struct Ticker {
    target: ActorId,
    period: Time,
    remaining: u32,
    sent: u32,
}

impl Actor<TestMsg> for Ticker {
    fn on_start(&mut self, ctx: &mut Ctx<'_, TestMsg>) {
        ctx.send_self_in(self.period, TestMsg::Tick);
    }

    fn on_event(&mut self, msg: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
        if let TestMsg::Tick = msg {
            if self.remaining == 0 {
                return;
            }
            self.remaining -= 1;
            self.sent += 1;
            ctx.send(self.target, TestMsg::Ping(self.sent));
            ctx.send_self_in(self.period, TestMsg::Tick);
        }
    }
}

/// Forwards Fwd(next, v) as Ping(v) after a fixed hop delay.
struct Hop {
    delay: Time,
}

impl Actor<TestMsg> for Hop {
    fn on_event(&mut self, msg: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
        if let TestMsg::Fwd(next, v) = msg {
            ctx.send_in(self.delay, next, TestMsg::Ping(v));
        }
    }
}

fn recorder(engine: &mut Engine<TestMsg>) -> (ActorId, Rc<RefCell<Vec<(Time, u32)>>>) {
    let log = Rc::new(RefCell::new(Vec::new()));
    let id = engine.add_actor(Box::new(Recorder { log: log.clone() }));
    (id, log)
}

#[test]
fn events_deliver_in_time_order() {
    let mut engine = Engine::new(1);
    let (rec, log) = recorder(&mut engine);
    engine.schedule(30, rec, TestMsg::Ping(3));
    engine.schedule(10, rec, TestMsg::Ping(1));
    engine.schedule(20, rec, TestMsg::Ping(2));
    engine.run_to_quiescence();
    assert_eq!(*log.borrow(), vec![(10, 1), (20, 2), (30, 3)]);
}

#[test]
fn same_timestamp_is_fifo() {
    let mut engine = Engine::new(1);
    let (rec, log) = recorder(&mut engine);
    for v in 0..100 {
        engine.schedule(5, rec, TestMsg::Ping(v));
    }
    engine.run_to_quiescence();
    let got: Vec<u32> = log.borrow().iter().map(|&(_, v)| v).collect();
    assert_eq!(got, (0..100).collect::<Vec<_>>());
}

#[test]
fn run_until_stops_at_horizon() {
    let mut engine = Engine::new(1);
    let (rec, log) = recorder(&mut engine);
    let _ticker = engine.add_actor(Box::new(Ticker {
        target: rec,
        period: 10,
        remaining: 1000,
        sent: 0,
    }));
    engine.run_until(55);
    assert_eq!(log.borrow().len(), 5); // ticks at 10..=50
    assert_eq!(engine.now(), 55);
    engine.run_until(100);
    assert_eq!(log.borrow().len(), 10);
}

#[test]
fn drain_stops_clock_at_last_event_not_horizon() {
    let mut engine = Engine::new(1);
    let (rec, log) = recorder(&mut engine);
    let _ticker = engine.add_actor(Box::new(Ticker {
        target: rec,
        period: 10,
        remaining: 5,
        sent: 0,
    }));
    let n = engine.drain();
    assert_eq!(log.borrow().len(), 5);
    // Unlike run_until(MAX), the clock sits at the last delivered event
    // (the ticker's final no-op tick at t=60): the real plane pumps
    // drain() between socket polls and per-second metric buckets must
    // stay finite.
    assert_eq!(engine.now(), 60);
    assert!(n >= 5);
    // A later external event resumes from there and drains again.
    engine.schedule(70, rec, TestMsg::Ping(99));
    assert_eq!(engine.drain(), 1);
    assert_eq!(engine.now(), 70);
    assert_eq!(log.borrow().last(), Some(&(70, 99)));
}

#[test]
fn on_start_runs_once() {
    let mut engine = Engine::new(1);
    let (rec, log) = recorder(&mut engine);
    engine.add_actor(Box::new(Ticker { target: rec, period: 7, remaining: 2, sent: 0 }));
    engine.run_until(3); // before first tick: start must have scheduled it
    assert!(log.borrow().is_empty());
    engine.run_until(20);
    assert_eq!(log.borrow().len(), 2);
}

#[test]
fn chained_hops_accumulate_delay() {
    let mut engine = Engine::new(1);
    let (rec, log) = recorder(&mut engine);
    let hop = engine.add_actor(Box::new(Hop { delay: 25 }));
    engine.schedule(100, hop, TestMsg::Fwd(rec, 9));
    engine.run_to_quiescence();
    assert_eq!(*log.borrow(), vec![(125, 9)]);
}

#[test]
fn determinism_same_seed_same_trace() {
    let run = |seed: u64| {
        let mut engine = Engine::new(seed);
        let (rec, log) = recorder(&mut engine);
        let hop = engine.add_actor(Box::new(Hop { delay: 3 }));
        engine.add_actor(Box::new(Ticker { target: rec, period: 11, remaining: 50, sent: 0 }));
        engine.schedule(1, hop, TestMsg::Fwd(rec, 77));
        engine.run_until(600);
        let trace = log.borrow().clone();
        trace
    };
    assert_eq!(run(42), run(42));
}

#[test]
#[should_panic(expected = "unregistered")]
fn send_to_unregistered_actor_panics() {
    struct Bad;
    impl Actor<TestMsg> for Bad {
        fn on_event(&mut self, _m: TestMsg, ctx: &mut Ctx<'_, TestMsg>) {
            ctx.send(ActorId(999), TestMsg::Tick);
        }
    }
    let mut engine = Engine::new(1);
    let bad = engine.add_actor(Box::new(Bad));
    engine.schedule(0, bad, TestMsg::Tick);
    engine.run_to_quiescence();
}

mod pool {
    use super::*;

    #[test]
    fn starts_immediately_when_core_free() {
        let mut pool = CorePool::new(2);
        assert!(pool.submit(0, Job { cost: 10, tag: 1 }).is_some());
        assert!(pool.submit(0, Job { cost: 10, tag: 2 }).is_some());
        assert_eq!(pool.busy(), 2);
    }

    #[test]
    fn queues_when_saturated_fifo_resume() {
        let mut pool = CorePool::new(1);
        assert!(pool.submit(0, Job { cost: 10, tag: 1 }).is_some());
        assert!(pool.submit(0, Job { cost: 10, tag: 2 }).is_none());
        assert!(pool.submit(0, Job { cost: 10, tag: 3 }).is_none());
        assert_eq!(pool.queued(), 2);
        let next = pool.on_complete(10).expect("tag 2 resumes");
        assert_eq!(next.tag, 2);
        let next = pool.on_complete(20).expect("tag 3 resumes");
        assert_eq!(next.tag, 3);
        assert!(pool.on_complete(30).is_none());
        assert_eq!(pool.busy(), 0);
    }

    #[test]
    fn utilization_accounts_busy_time() {
        let mut pool = CorePool::new(2);
        pool.submit(0, Job { cost: 100, tag: 1 }).unwrap();
        pool.on_complete(100);
        // one of two cores busy for 100 of 200 ns -> 25%
        let u = pool.utilization(200);
        assert!((u - 0.25).abs() < 1e-9, "{u}");
    }

    #[test]
    fn queue_peak_tracks_high_water() {
        let mut pool = CorePool::new(1);
        pool.submit(0, Job { cost: 1, tag: 0 });
        for t in 1..=5 {
            pool.submit(0, Job { cost: 1, tag: t });
        }
        assert_eq!(pool.queue_peak(), 5);
    }

    #[test]
    #[should_panic]
    fn zero_core_pool_is_a_bug() {
        CorePool::new(0);
    }
}

mod rng {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn next_below_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert!(rng.next_below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut rng = Rng::new(4);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            match rng.range(5, 8) {
                5 => lo_seen = true,
                8 => hi_seen = true,
                v => assert!((5..=8).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(5);
        let mut acc = 0.0;
        for _ in 0..1000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            acc += v;
        }
        assert!((acc / 1000.0 - 0.5).abs() < 0.05, "mean off: {}", acc / 1000.0);
    }

    #[test]
    fn fill_bytes_covers_partial_chunks() {
        let mut rng = Rng::new(6);
        let mut buf = [0u8; 13];
        rng.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut f1 = base.fork();
        let mut f2 = base.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
