//! Minimal property-testing harness (the offline vendor set has no
//! proptest crate): deterministic random-case generation with automatic
//! seed reporting on failure.
//!
//! ```ignore
//! forall(100, |rng| {
//!     let n = rng.range(1, 64);
//!     ... assertions ...
//! });
//! ```
//!
//! Failures re-panic with the case seed so the exact case can be replayed
//! by seeding [`Rng`] directly.

use super::Rng;

/// Run `f` on `cases` deterministic random cases. On panic, report which
/// case seed failed before propagating.
pub fn forall(cases: u64, f: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xF0A11 ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (Rng seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}
