//! Deterministic xorshift64* RNG.
//!
//! The offline vendor set has no `rand` crate; the simulation only needs a
//! small, fast, seedable generator whose streams are reproducible across
//! runs and platforms — xorshift64* is plenty.

/// Deterministic 64-bit generator (xorshift64*).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point; fold the seed through splitmix64
        // so nearby seeds give unrelated streams.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        Self { state: if z == 0 { 0xDEAD_BEEF_CAFE_F00D } else { z } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift bound; bias is negligible for simulation purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// One byte, uniform.
    pub fn next_byte(&mut self) -> u8 {
        (self.next_u64() >> 56) as u8
    }

    /// Fill a byte slice.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }

    /// Fork an independent deterministic stream (for per-actor RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}
