//! Deterministic discrete-event simulation (DES) engine.
//!
//! The paper's evaluation ran on 128-core Aion nodes with 100 Gb/s
//! Infiniband; this host has one core, so real thread-per-core concurrency
//! cannot reproduce any of the contention effects the paper measures
//! (DESIGN.md §2, substitution 1). Instead, every schedulable entity of the
//! streaming architecture — broker dispatcher, broker worker cores, the
//! dedicated push thread, producers, source readers, operator tasks, the
//! network — is an [`Actor`] driven by this engine in *virtual* time.
//!
//! The engine is deliberately minimal and fully deterministic:
//! * a binary-heap event queue ordered by `(time, seq)` — FIFO among
//!   same-timestamp events, so runs are reproducible bit-for-bit;
//! * actors own their state and communicate only through messages
//!   scheduled via [`Ctx`];
//! * shared blackboards (network, object store, metrics) are `Rc<RefCell>`
//!   handles held by the actors that need them — the engine itself is
//!   single-threaded, which is exactly what makes that sound.
//!
//! The engine is generic over the message type so it can be unit-tested
//! in isolation (see `tests.rs`) and reused by any component.

mod engine;
mod pool;
pub mod proptest;
mod rng;
#[cfg(test)]
mod tests;

pub use engine::{Actor, ActorId, Ctx, Engine};
pub use pool::{CorePool, Job};
pub use rng::Rng;

/// Virtual time in nanoseconds since simulation start.
pub type Time = u64;

/// One virtual second, in [`Time`] units.
pub const SECOND: Time = 1_000_000_000;

/// One virtual millisecond.
pub const MILLIS: Time = 1_000_000;

/// One virtual microsecond.
pub const MICROS: Time = 1_000;

/// Convert a f64 number of seconds to [`Time`].
pub fn secs(s: f64) -> Time {
    (s * SECOND as f64) as Time
}
