//! FIFO multi-server resource — the model of a CPU core pool.
//!
//! The paper's broker is "configured with one dispatcher thread (one CPU
//! core) polling the network ... and multiple working threads that do the
//! actual writes and reads" (§IV-A). Both are [`CorePool`]s: the dispatcher
//! a pool of one, the workers a pool of `NBc`. Producer/consumer RPC
//! *interference* — the effect the whole paper is about — is queueing at
//! these pools.
//!
//! The pool is passive (no events of its own): the owning actor submits
//! jobs, schedules a completion self-message for each started job, and asks
//! the pool for the next queued job when one finishes.

use std::collections::VecDeque;

use super::Time;

/// A unit of work for a core: a service time plus an opaque tag the owner
/// uses to resume the RPC/task that was waiting for the core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Job {
    /// Service time on one core.
    pub cost: Time,
    /// Owner-defined identifier of the waiting work item.
    pub tag: u64,
}

/// FIFO queue in front of `cores` identical servers.
#[derive(Debug)]
pub struct CorePool {
    cores: usize,
    busy: usize,
    queue: VecDeque<Job>,
    // instrumentation
    jobs_started: u64,
    busy_ns_accum: u64,
    last_change: Time,
    queue_peak: usize,
}

impl CorePool {
    pub fn new(cores: usize) -> Self {
        assert!(cores > 0, "a core pool needs at least one core");
        Self {
            cores,
            busy: 0,
            queue: VecDeque::new(),
            jobs_started: 0,
            busy_ns_accum: 0,
            last_change: 0,
            queue_peak: 0,
        }
    }

    /// Submit a job. If a core is free the job starts immediately and is
    /// returned — the owner must schedule its completion at `now + cost`.
    /// Otherwise it queues and `None` is returned.
    pub fn submit(&mut self, now: Time, job: Job) -> Option<Job> {
        if self.busy < self.cores {
            self.note(now);
            self.busy += 1;
            self.jobs_started += 1;
            Some(job)
        } else {
            self.queue.push_back(job);
            self.queue_peak = self.queue_peak.max(self.queue.len());
            None
        }
    }

    /// A job finished: free its core and, if work is queued, start the next
    /// job (returned; owner schedules its completion at `now + cost`).
    pub fn on_complete(&mut self, now: Time) -> Option<Job> {
        debug_assert!(self.busy > 0, "completion without a running job");
        self.note(now);
        self.busy -= 1;
        if let Some(job) = self.queue.pop_front() {
            self.busy += 1;
            self.jobs_started += 1;
            Some(job)
        } else {
            None
        }
    }

    fn note(&mut self, now: Time) {
        self.busy_ns_accum += self.busy as u64 * (now - self.last_change);
        self.last_change = now;
    }

    pub fn cores(&self) -> usize {
        self.cores
    }

    pub fn busy(&self) -> usize {
        self.busy
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn queue_peak(&self) -> usize {
        self.queue_peak
    }

    pub fn jobs_started(&self) -> u64 {
        self.jobs_started
    }

    /// Mean utilisation in `[0, 1]` over `[0, now]` (per core).
    pub fn utilization(&mut self, now: Time) -> f64 {
        self.note(now);
        if now == 0 {
            return 0.0;
        }
        self.busy_ns_accum as f64 / (self.cores as f64 * now as f64)
    }
}
