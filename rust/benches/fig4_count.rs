//! Regenerates the paper's fig4 series. See experiments::fig4 for the
//! parameterisation and the expected shape.
mod common;

fn main() {
    let spec = zettastream::experiments::fig4(common::bench_duration(), &common::chunk_sweep());
    common::run(&spec);
}
