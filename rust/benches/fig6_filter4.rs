//! Regenerates the paper's fig6 series. See experiments::fig6 for the
//! parameterisation and the expected shape.
mod common;

fn main() {
    let spec = zettastream::experiments::fig6(common::bench_duration(), &common::chunk_sweep());
    common::run(&spec);
}
