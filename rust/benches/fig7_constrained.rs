//! Regenerates the paper's fig7 series. See experiments::fig7 for the
//! parameterisation and the expected shape.
mod common;

fn main() {
    let spec = zettastream::experiments::fig7(common::bench_duration(), &common::chunk_sweep());
    common::run(&spec);
}
