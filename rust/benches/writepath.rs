//! Regenerates the write-path ablation: the three producer backends
//! (sync / pipelined / sharedmem) against the pull/push/hybrid sources on
//! the Fig. 3 ingestion workload. See experiments::ablation_writepath.
mod common;

fn main() {
    let spec = zettastream::experiments::ablation_writepath(
        common::bench_duration(),
        &common::chunk_sweep(),
    );
    common::run(&spec);
}
