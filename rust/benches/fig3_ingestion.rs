//! Regenerates the paper's fig3 series. See experiments::fig3 for the
//! parameterisation and the expected shape.
mod common;

fn main() {
    let spec = zettastream::experiments::fig3(common::bench_duration(), &common::chunk_sweep());
    common::run(&spec);
}
