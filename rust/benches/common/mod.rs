//! Shared bench-harness plumbing (criterion is not in the offline vendor
//! set; benches are plain `harness = false` binaries driven by `cargo
//! bench`). Environment knobs:
//!   ZETTA_BENCH_SECS   virtual seconds per row (default 30)
//!   ZETTA_BENCH_QUICK  set to shrink the chunk sweep to {4,32,128} KiB
use std::time::Instant;

use zettastream::experiments::FigureSpec;

pub fn bench_duration() -> u64 {
    std::env::var("ZETTA_BENCH_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(12)
}

#[allow(dead_code)]
pub fn chunk_sweep() -> Vec<usize> {
    if std::env::var_os("ZETTA_BENCH_QUICK").is_some() {
        vec![4, 32, 128]
    } else {
        zettastream::experiments::CHUNK_SIZES_KIB.to_vec()
    }
}

/// Run a figure and report wall time + simulated-vs-wall speed.
pub fn run(spec: &FigureSpec) {
    let t0 = Instant::now();
    let summaries = zettastream::experiments::run_figure(spec);
    let wall = t0.elapsed().as_secs_f64();
    let virtual_s: u64 = spec.rows.iter().map(|(_, c)| c.duration_secs).sum();
    println!(
        "-- {}: {} rows, {:.1}s wall for {}s virtual ({:.1}x real time), {} runs ok",
        spec.id,
        spec.rows.len(),
        wall,
        virtual_s,
        virtual_s as f64 / wall.max(1e-9),
        summaries.len()
    );
}
