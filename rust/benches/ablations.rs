//! Ablation benches beyond the paper's figures (DESIGN.md §4): push
//! object-pool size, network profile, pull timeout, push fan-in, credit
//! window.
mod common;

fn main() {
    for spec in zettastream::experiments::ablations(common::bench_duration()) {
        common::run(&spec);
        println!();
    }
}
