//! Hot-path micro-benchmarks: the profiling harness for the perf pass
//! (EXPERIMENTS.md §Perf).
//!
//! The DES-side measurements (engine ping-pong, the cluster-sim target,
//! and the full 4-source × 3-write sweep) live in
//! `zettastream::experiments::hotpath`, shared with `zettastream bench
//! hotpath`, and are recorded to `BENCH_hotpath.json` so the perf
//! trajectory accumulates across runs. This binary adds the real compute
//! path on top: native vs PJRT/XLA kernels, ns/record.

use std::rc::Rc;

use zettastream::compute::ComputeEngine;
use zettastream::experiments::hotpath;
use zettastream::proto::Chunk;
use zettastream::wikipedia::CorpusReader;

fn bench_compute() {
    let mut reader = CorpusReader::new(2048, 64);
    let mut text = vec![0u8; 64 * 2048];
    reader.fill_records(&mut text);
    let text_chunk = Chunk::real(64, 2048, Rc::new(text));
    let mut synth = vec![b'q'; 1024 * 100];
    synth[51_200..51_206].copy_from_slice(b"needle");
    let synth_chunk = Chunk::real(1024, 100, Rc::new(synth));

    let native = ComputeEngine::native();
    for _ in 0..200 {
        native.filter_count(&synth_chunk, b"needle").unwrap();
    }
    let st = native.stats();
    println!(
        "compute[native filter]: {:.0} ns/record",
        st.wall_ns as f64 / st.records_processed as f64
    );
    let native = ComputeEngine::native();
    for _ in 0..50 {
        native.wordcount(&text_chunk).unwrap();
    }
    let st = native.stats();
    println!(
        "compute[native wordcount]: {:.0} ns/record (2 KiB text)",
        st.wall_ns as f64 / st.records_processed as f64
    );

    match ComputeEngine::xla_from_default_dir() {
        Ok(warm) => {
            warm.filter_count(&synth_chunk, b"needle").unwrap(); // JIT warm-up
            let xla = ComputeEngine::xla_from_default_dir().unwrap();
            for _ in 0..50 {
                xla.filter_count(&synth_chunk, b"needle").unwrap();
            }
            let st = xla.stats();
            println!(
                "compute[xla filter, PJRT]: {:.0} ns/record",
                st.wall_ns as f64 / st.records_processed as f64
            );
            let xla2 = ComputeEngine::xla_from_default_dir().unwrap();
            for _ in 0..10 {
                xla2.wordcount(&text_chunk).unwrap();
            }
            let st = xla2.stats();
            println!(
                "compute[xla wordcount, PJRT]: {:.0} ns/record (interpret-lowered scan)",
                st.wall_ns as f64 / st.records_processed as f64
            );
        }
        Err(e) => println!("compute[xla]: skipped ({e:#})"),
    }
}

fn main() {
    let quick = std::env::var_os("ZETTA_BENCH_QUICK").is_some();
    hotpath::run_and_record(quick, std::path::Path::new("BENCH_hotpath.json"));
    bench_compute();
}
