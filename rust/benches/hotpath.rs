//! Hot-path micro-benchmarks: the profiling harness for the perf pass
//! (EXPERIMENTS.md §Perf). Measures, in isolation:
//!
//!   * DES engine event throughput (events/s) — the simulator's own cost;
//!   * whole-cluster simulation speed (virtual-vs-wall ratio);
//!   * the real compute path: native vs PJRT/XLA kernels, ns/record.

mod common;

use std::rc::Rc;
use std::time::Instant;

use zettastream::cluster::launch;
use zettastream::compute::ComputeEngine;
use zettastream::config::{parse_overrides, ExperimentConfig};
use zettastream::proto::Chunk;
use zettastream::sim::{Actor, ActorId, Ctx, Engine};
use zettastream::wikipedia::CorpusReader;

struct PingPong {
    peer: Option<ActorId>,
    left: u64,
}

impl Actor<u32> for PingPong {
    fn on_start(&mut self, ctx: &mut Ctx<'_, u32>) {
        if self.peer.is_some() {
            ctx.send_self_in(1, 0);
        }
    }
    fn on_event(&mut self, _m: u32, ctx: &mut Ctx<'_, u32>) {
        if self.left == 0 {
            return;
        }
        self.left -= 1;
        match self.peer {
            Some(peer) => ctx.send_in(1, peer, 0),
            None => ctx.send_self_in(1, 0),
        }
    }
}

fn bench_engine() {
    const N: u64 = 2_000_000;
    let mut engine: Engine<u32> = Engine::new(1);
    let a = engine.add_actor(Box::new(PingPong { peer: None, left: N }));
    let _b = engine.add_actor(Box::new(PingPong { peer: Some(a), left: N }));
    let t0 = Instant::now();
    engine.run_to_quiescence();
    let dt = t0.elapsed();
    let evps = engine.events_processed() as f64 / dt.as_secs_f64();
    println!(
        "engine: {} events in {:.2}s -> {:.1} M events/s ({:.0} ns/event)",
        engine.events_processed(),
        dt.as_secs_f64(),
        evps / 1e6,
        1e9 / evps
    );
}

fn bench_cluster_speed(label: &str, overrides: &[&str]) {
    let mut c = ExperimentConfig { duration_secs: 20, warmup_secs: 2, ..Default::default() };
    c.apply(&parse_overrides(overrides.iter().copied()).unwrap()).unwrap();
    let t0 = Instant::now();
    let cluster = launch(&c, None);
    let mut engine = cluster.engine;
    engine.run_until(c.duration_secs * zettastream::sim::SECOND);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "cluster[{label}]: {}s virtual in {:.2}s wall ({:.1}x), {:.2} M events/s",
        c.duration_secs,
        wall,
        c.duration_secs as f64 / wall,
        engine.events_processed() as f64 / wall / 1e6,
    );
}

fn bench_compute() {
    let mut reader = CorpusReader::new(2048, 64);
    let mut text = vec![0u8; 64 * 2048];
    reader.fill_records(&mut text);
    let text_chunk = Chunk::real(64, 2048, Rc::new(text));
    let mut synth = vec![b'q'; 1024 * 100];
    synth[51_200..51_206].copy_from_slice(b"needle");
    let synth_chunk = Chunk::real(1024, 100, Rc::new(synth));

    let native = ComputeEngine::native();
    for _ in 0..200 {
        native.filter_count(&synth_chunk, b"needle").unwrap();
    }
    let st = native.stats();
    println!(
        "compute[native filter]: {:.0} ns/record",
        st.wall_ns as f64 / st.records_processed as f64
    );
    let native = ComputeEngine::native();
    for _ in 0..50 {
        native.wordcount(&text_chunk).unwrap();
    }
    let st = native.stats();
    println!(
        "compute[native wordcount]: {:.0} ns/record (2 KiB text)",
        st.wall_ns as f64 / st.records_processed as f64
    );

    match ComputeEngine::xla_from_default_dir() {
        Ok(warm) => {
            warm.filter_count(&synth_chunk, b"needle").unwrap(); // JIT warm-up
            let xla = ComputeEngine::xla_from_default_dir().unwrap();
            for _ in 0..50 {
                xla.filter_count(&synth_chunk, b"needle").unwrap();
            }
            let st = xla.stats();
            println!(
                "compute[xla filter, PJRT]: {:.0} ns/record",
                st.wall_ns as f64 / st.records_processed as f64
            );
            let xla2 = ComputeEngine::xla_from_default_dir().unwrap();
            for _ in 0..10 {
                xla2.wordcount(&text_chunk).unwrap();
            }
            let st = xla2.stats();
            println!(
                "compute[xla wordcount, PJRT]: {:.0} ns/record (interpret-lowered scan)",
                st.wall_ns as f64 / st.records_processed as f64
            );
        }
        Err(e) => println!("compute[xla]: skipped ({e:#})"),
    }
}

fn main() {
    println!("== hotpath micro-benchmarks ==");
    bench_engine();
    bench_cluster_speed("pull-4x4", &["mode=pull", "np=4", "nc=4"]);
    bench_cluster_speed("push-4x4", &["mode=push", "np=4", "nc=4"]);
    bench_cluster_speed("wordcount", &["mode=push", "workload=wordcount", "recs=2048"]);
    bench_compute();
}
