//! Regenerates the paper's fig5 series. See experiments::fig5 for the
//! parameterisation and the expected shape.
mod common;

fn main() {
    let spec = zettastream::experiments::fig5(common::bench_duration(), &common::chunk_sweep());
    common::run(&spec);
}
