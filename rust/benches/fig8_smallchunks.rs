//! Regenerates the paper's Fig. 8 series (small chunks, consumer CS = 8x).
mod common;

fn main() {
    let spec = zettastream::experiments::fig8(common::bench_duration());
    common::run(&spec);
}
