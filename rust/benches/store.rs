//! Regenerates the storage-tier ablation: the in-memory broker log vs the
//! durable WAL + sorted-segment backend across the source x write design
//! space. See experiments::ablation_store.
mod common;

fn main() {
    let spec = zettastream::experiments::ablation_store(common::bench_duration());
    common::run(&spec);
}
