//! Regenerates the paper's Fig. 9 series ((windowed) word count).
mod common;

fn main() {
    let spec = zettastream::experiments::fig9(common::bench_duration());
    common::run(&spec);
}
