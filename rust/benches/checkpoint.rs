//! Regenerates the checkpoint & recovery ablation: checkpoint overhead,
//! barrier alignment time and recovery time across the pull/push/hybrid
//! sources x sync/pipelined/sharedmem writers.
//! See experiments::ablation_checkpoint.
mod common;

fn main() {
    let spec = zettastream::experiments::ablation_checkpoint(common::bench_duration());
    common::run(&spec);
}
